//! The unified request-source subsystem.
//!
//! Everything the simulated cores consume is a [`RequestSource`]: a
//! batched stream of [`MemRef`]s refilled through `fill` (no per-reference
//! virtual dispatch on the `mem::Core` hot loop). Three implementations
//! live here:
//!
//! * [`Generator`] — the 35-workload synthetic suite standing in for the
//!   paper's Fig-4/6 application mix (SPEC CPU2006, STREAM, TPC,
//!   GUPS-style kernels), each parameterized by memory intensity (MPKI),
//!   access pattern, read/write mix and footprint;
//! * [`trace`] — recorded request streams: a versioned compact binary
//!   format (delta-encoded, streaming, bounded memory) plus a
//!   DRAMSim3-compatible text format for interop;
//! * [`mix`] — named multi-programmed mixes (intensive × non-intensive
//!   pairings) for the paper's multi-core evaluation.

pub mod arrival;
pub mod fuzz;
pub mod mix;
pub mod trace;

use crate::util::rng::Rng;

/// One memory reference produced by a request source.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemRef {
    /// Non-memory instructions retired before this reference.
    pub gap_insts: u32,
    pub addr: u64,
    pub is_write: bool,
    /// Dependent load (pointer chase): must wait for prior misses.
    pub dependent: bool,
}

/// How many references a source appends per `fill` call (the `mem::Core`
/// consumption batch — one virtual call amortized over this many refs).
pub const SOURCE_BATCH: usize = 64;

/// A batched stream of memory references.
///
/// `fill` appends up to [`SOURCE_BATCH`] references to `out` and returns
/// how many were appended; 0 means the source is exhausted (finite trace
/// sources — synthetic generators are infinite and always return a full
/// batch). The consumer owns the buffer, so a refill is one virtual call
/// per batch instead of one per reference.
pub trait RequestSource {
    fn fill(&mut self, out: &mut Vec<MemRef>) -> usize;
}

/// The empty source: immediately exhausted. Placeholder used when a
/// core's source is temporarily taken (e.g. while wrapping it in a
/// recorder) and a valid end-of-stream default elsewhere.
pub struct NullSource;

impl RequestSource for NullSource {
    fn fill(&mut self, _out: &mut Vec<MemRef>) -> usize {
        0
    }
}

/// A request source with identity: the workload (or trace stream) name,
/// the seed label it was instantiated with, and its footprint — the
/// metadata `mem::System` carries per core and the trace recorder writes
/// into the file header.
pub struct NamedSource {
    pub name: String,
    pub seed: String,
    /// Footprint in bytes (0 when unknown, e.g. an imported text trace).
    pub footprint: u64,
    pub source: Box<dyn RequestSource>,
}

/// Access-pattern families.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pattern {
    /// Sequential streaming (row-buffer friendly, STREAM-like).
    Stream,
    /// Uniform random lines over the footprint (GUPS/mcf-like).
    Random,
    /// Dependent pointer chase (mlp = 1).
    PointerChase,
    /// Multiple concurrent sequential streams (stencil/lbm-like).
    MultiStream(u32),
    /// Mixture of stream and random (xalancbmk/omnetpp-like).
    Mixed,
    /// Sequential stream whose intensity is phased in time: references in
    /// the active window keep the MPKI-derived gap, idle references carry
    /// one `idle_gap`-instruction pause. `repeat: false` is a front-loaded
    /// burst-then-idle profile; `repeat: true` re-bursts every
    /// `active_refs` references. Exercises the thermal model's response to
    /// workload phases (windowed bus-utilization regression tests).
    Phased { active_refs: u64, idle_gap: u32, repeat: bool },
}

/// Static description of one workload.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub name: &'static str,
    pub pattern: Pattern,
    /// Last-level-cache misses per kilo-instruction.
    pub mpki: f64,
    /// Fraction of references that are writes.
    pub write_ratio: f64,
    /// Footprint in bytes (addresses wrap within it).
    pub footprint: u64,
}

impl WorkloadSpec {
    pub fn memory_intensive(&self) -> bool {
        self.mpki >= 10.0
    }

    /// Instantiate the generator with a per-(workload, core, rep) seed.
    pub fn source(&self, seed_label: &str) -> Box<dyn RequestSource> {
        self.source_with_batch(seed_label, SOURCE_BATCH)
    }

    /// [`WorkloadSpec::source`] with an explicit refill batch size — the
    /// SPEEDUP[SOURCE] benchmark compares `batch = 1` (the pre-batching
    /// one-virtual-call-per-reference regime) against the default.
    pub fn source_with_batch(&self, seed_label: &str, batch: usize)
                             -> Box<dyn RequestSource> {
        let rng = Rng::from_label(&format!("{}/{}", self.name, seed_label));
        Box::new(Generator::with_batch(self.clone(), rng, batch))
    }

    /// The source plus its identity metadata (what `mem::System` records
    /// per core and the trace recorder persists).
    pub fn named_source(&self, seed_label: &str) -> NamedSource {
        NamedSource {
            name: self.name.to_string(),
            seed: seed_label.to_string(),
            footprint: self.footprint,
            source: self.source(seed_label),
        }
    }
}

struct StreamState {
    pos: u64,
    base: u64,
}

/// The synthetic address-stream generator behind every suite workload.
pub struct Generator {
    spec: WorkloadSpec,
    rng: Rng,
    streams: Vec<StreamState>,
    next_stream: usize,
    chase_ptr: u64,
    /// References emitted so far (drives `Pattern::Phased` scheduling).
    phase_count: u64,
    batch: usize,
}

impl Generator {
    pub fn new(spec: WorkloadSpec, rng: Rng) -> Self {
        Generator::with_batch(spec, rng, SOURCE_BATCH)
    }

    fn with_batch(spec: WorkloadSpec, mut rng: Rng, batch: usize) -> Self {
        assert!(batch >= 1, "refill batch must be at least 1");
        let n_streams = match spec.pattern {
            Pattern::MultiStream(n) => n as usize,
            Pattern::Stream => 1,
            _ => 1,
        };
        // MultiStream models multi-array kernels (STREAM copy/add): the
        // arrays stride together, so their bases are aligned to the bank
        // rotation period (64 KiB for 8 banks x 8 KiB rows) and inter-array
        // switches hit the same bank in different rows — the row-conflict
        // behaviour real STREAM shows on an open-page controller.
        let bank_period = 64 * 1024u64;
        let streams = (0..n_streams)
            .map(|i| {
                let base = match spec.pattern {
                    Pattern::MultiStream(_) => {
                        rng.below(spec.footprint / bank_period) * bank_period
                    }
                    // Mixed: the streamed half lives in a contiguous,
                    // line-aligned half-footprint window, so base + pos
                    // never wraps across the footprint boundary and never
                    // aliases the random half mid-run.
                    Pattern::Mixed => {
                        rng.below(spec.footprint / 2 / 64) * 64
                    }
                    _ => rng.below(spec.footprint / 64) * 64,
                };
                let _ = i;
                StreamState { pos: 0, base }
            })
            .collect();
        let chase_ptr = rng.below(spec.footprint / 64) * 64;
        Generator { spec, rng, streams, next_stream: 0, chase_ptr,
                    phase_count: 0, batch }
    }

    fn gap(&mut self) -> u32 {
        // Geometric-ish gap with mean 1000/MPKI (>= 0).
        let mean = (1000.0 / self.spec.mpki).max(0.05);
        let u = self.rng.f64().max(1e-12);
        (-mean * u.ln()).round().min(1e7) as u32
    }

    fn rand_line(&mut self) -> u64 {
        self.rng.below(self.spec.footprint / 64) * 64
    }

    fn gen_ref(&mut self) -> MemRef {
        let mut gap = self.gap();
        let is_write = self.rng.chance(self.spec.write_ratio);
        let (addr, dependent) = match self.spec.pattern {
            Pattern::Stream | Pattern::MultiStream(_) => {
                let idx = self.next_stream;
                self.next_stream = (self.next_stream + 1) % self.streams.len();
                let per_stream = self.spec.footprint / self.streams.len() as u64;
                let s = &mut self.streams[idx];
                s.pos += 64;
                if s.pos >= per_stream {
                    s.pos = 0;
                }
                ((s.base + s.pos) % self.spec.footprint, false)
            }
            Pattern::Random => (self.rand_line(), false),
            Pattern::Phased { active_refs, idle_gap, repeat } => {
                let idx = self.phase_count;
                self.phase_count += 1;
                let active = if repeat {
                    idx % (active_refs + 1) < active_refs
                } else {
                    idx < active_refs
                };
                if !active {
                    gap = idle_gap;
                }
                let s = &mut self.streams[0];
                s.pos += 64;
                if s.pos >= self.spec.footprint {
                    s.pos = 0;
                }
                ((s.base + s.pos) % self.spec.footprint, false)
            }
            Pattern::PointerChase => {
                // Next pointer derived deterministically from the current
                // one (a fixed random permutation walk).
                let mut h = self.chase_ptr ^ 0x9E3779B97F4A7C15;
                h = h.wrapping_mul(0xBF58476D1CE4E5B9);
                h ^= h >> 31;
                self.chase_ptr = (h % (self.spec.footprint / 64)) * 64;
                (self.chase_ptr, true)
            }
            Pattern::Mixed => {
                if self.rng.chance(0.5) {
                    // Contiguous half-footprint window: pos wraps within
                    // the window, the address is always base + pos.
                    let half = self.spec.footprint / 2;
                    let s = &mut self.streams[0];
                    s.pos = (s.pos + 64) % half;
                    (s.base + s.pos, false)
                } else {
                    (self.rand_line(), false)
                }
            }
        };
        MemRef { gap_insts: gap, addr, is_write, dependent }
    }
}

impl RequestSource for Generator {
    fn fill(&mut self, out: &mut Vec<MemRef>) -> usize {
        for _ in 0..self.batch {
            let r = self.gen_ref();
            out.push(r);
        }
        self.batch
    }
}

const MB: u64 = 1024 * 1024;

/// The 35-workload pool (paper §6: 35 workloads spanning STREAM, SPEC,
/// TPC and GUPS-style behaviour in single- and multi-core configurations).
pub fn suite() -> Vec<WorkloadSpec> {
    use Pattern::*;
    vec![
        // --- STREAM-like bandwidth kernels (very memory intensive) ------
        WorkloadSpec { name: "stream.copy", pattern: MultiStream(2), mpki: 45.0, write_ratio: 0.50, footprint: 512 * MB },
        WorkloadSpec { name: "stream.scale", pattern: MultiStream(2), mpki: 42.0, write_ratio: 0.50, footprint: 512 * MB },
        WorkloadSpec { name: "stream.add", pattern: MultiStream(3), mpki: 40.0, write_ratio: 0.33, footprint: 512 * MB },
        WorkloadSpec { name: "stream.triad", pattern: MultiStream(3), mpki: 38.0, write_ratio: 0.33, footprint: 512 * MB },
        // --- GUPS / random-access -------------------------------------
        WorkloadSpec { name: "gups", pattern: Random, mpki: 35.0, write_ratio: 0.5, footprint: 1024 * MB },
        WorkloadSpec { name: "rand.read", pattern: Random, mpki: 30.0, write_ratio: 0.0, footprint: 1024 * MB },
        // --- SPEC-like memory-intensive --------------------------------
        WorkloadSpec { name: "mcf", pattern: PointerChase, mpki: 28.0, write_ratio: 0.10, footprint: 768 * MB },
        WorkloadSpec { name: "lbm", pattern: MultiStream(4), mpki: 26.0, write_ratio: 0.40, footprint: 512 * MB },
        WorkloadSpec { name: "milc", pattern: Mixed, mpki: 22.0, write_ratio: 0.25, footprint: 512 * MB },
        WorkloadSpec { name: "libquantum", pattern: Stream, mpki: 24.0, write_ratio: 0.20, footprint: 256 * MB },
        WorkloadSpec { name: "soplex", pattern: Mixed, mpki: 20.0, write_ratio: 0.20, footprint: 384 * MB },
        WorkloadSpec { name: "gcc.s04", pattern: Mixed, mpki: 18.0, write_ratio: 0.30, footprint: 256 * MB },
        WorkloadSpec { name: "sphinx3", pattern: Mixed, mpki: 16.0, write_ratio: 0.15, footprint: 256 * MB },
        WorkloadSpec { name: "omnetpp", pattern: PointerChase, mpki: 15.0, write_ratio: 0.25, footprint: 384 * MB },
        WorkloadSpec { name: "leslie3d", pattern: MultiStream(2), mpki: 14.0, write_ratio: 0.35, footprint: 384 * MB },
        WorkloadSpec { name: "gems", pattern: MultiStream(2), mpki: 14.0, write_ratio: 0.30, footprint: 512 * MB },
        WorkloadSpec { name: "zeusmp", pattern: MultiStream(3), mpki: 12.0, write_ratio: 0.35, footprint: 384 * MB },
        WorkloadSpec { name: "cactus", pattern: Mixed, mpki: 12.0, write_ratio: 0.30, footprint: 384 * MB },
        WorkloadSpec { name: "wrf", pattern: Mixed, mpki: 11.0, write_ratio: 0.30, footprint: 256 * MB },
        WorkloadSpec { name: "bwaves", pattern: MultiStream(2), mpki: 11.0, write_ratio: 0.25, footprint: 512 * MB },
        WorkloadSpec { name: "tpcc64", pattern: Random, mpki: 13.0, write_ratio: 0.35, footprint: 1024 * MB },
        WorkloadSpec { name: "tpch2", pattern: Mixed, mpki: 10.0, write_ratio: 0.10, footprint: 768 * MB },
        // --- non-memory-intensive ---------------------------------------
        WorkloadSpec { name: "apache2", pattern: Mixed, mpki: 2.0, write_ratio: 0.25, footprint: 256 * MB },
        WorkloadSpec { name: "gcc.166", pattern: Mixed, mpki: 1.5, write_ratio: 0.30, footprint: 128 * MB },
        WorkloadSpec { name: "astar", pattern: PointerChase, mpki: 1.2, write_ratio: 0.20, footprint: 192 * MB },
        WorkloadSpec { name: "bzip2", pattern: Stream, mpki: 1.0, write_ratio: 0.35, footprint: 128 * MB },
        WorkloadSpec { name: "h264ref", pattern: Mixed, mpki: 0.8, write_ratio: 0.25, footprint: 96 * MB },
        WorkloadSpec { name: "gobmk", pattern: Mixed, mpki: 0.6, write_ratio: 0.25, footprint: 64 * MB },
        WorkloadSpec { name: "sjeng", pattern: Mixed, mpki: 0.5, write_ratio: 0.25, footprint: 128 * MB },
        WorkloadSpec { name: "hmmer", pattern: Stream, mpki: 0.5, write_ratio: 0.20, footprint: 64 * MB },
        WorkloadSpec { name: "perlbench", pattern: Mixed, mpki: 0.4, write_ratio: 0.30, footprint: 64 * MB },
        WorkloadSpec { name: "namd", pattern: Stream, mpki: 0.3, write_ratio: 0.15, footprint: 96 * MB },
        WorkloadSpec { name: "calculix", pattern: Mixed, mpki: 0.25, write_ratio: 0.20, footprint: 64 * MB },
        WorkloadSpec { name: "povray", pattern: Mixed, mpki: 0.15, write_ratio: 0.20, footprint: 32 * MB },
        WorkloadSpec { name: "gamess", pattern: Stream, mpki: 0.1, write_ratio: 0.15, footprint: 32 * MB },
    ]
}

/// Look a workload up by name.
pub fn by_name(name: &str) -> Option<WorkloadSpec> {
    suite().into_iter().find(|w| w.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// One-reference-at-a-time view over a batched source (test helper).
    pub struct Pull {
        src: Box<dyn RequestSource>,
        buf: Vec<MemRef>,
        pos: usize,
    }

    impl Pull {
        pub fn new(src: Box<dyn RequestSource>) -> Self {
            Pull { src, buf: Vec::new(), pos: 0 }
        }

        pub fn take_one(&mut self) -> MemRef {
            if self.pos == self.buf.len() {
                self.buf.clear();
                self.pos = 0;
                let n = self.src.fill(&mut self.buf);
                assert!(n > 0, "source exhausted");
            }
            let r = self.buf[self.pos];
            self.pos += 1;
            r
        }
    }

    fn pull(w: &WorkloadSpec, seed: &str) -> Pull {
        Pull::new(w.source(seed))
    }

    #[test]
    fn suite_has_35_unique_workloads() {
        let s = suite();
        assert_eq!(s.len(), 35);
        let mut names: Vec<&str> = s.iter().map(|w| w.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 35);
    }

    #[test]
    fn both_intensity_groups_present() {
        let s = suite();
        let hi = s.iter().filter(|w| w.memory_intensive()).count();
        let lo = s.len() - hi;
        assert!(hi >= 15, "{hi} intensive");
        assert!(lo >= 10, "{lo} non-intensive");
    }

    #[test]
    fn sources_are_deterministic_per_seed() {
        let w = by_name("mcf").unwrap();
        let mut a = pull(&w, "core0/rep0");
        let mut b = pull(&w, "core0/rep0");
        let mut c = pull(&w, "core0/rep1");
        let (ra, rb, rc) = (a.take_one(), b.take_one(), c.take_one());
        assert_eq!(ra, rb);
        // Different rep starts elsewhere (pointer chase seed differs).
        assert_ne!(ra.addr, rc.addr);
    }

    #[test]
    fn batch_size_does_not_change_the_stream() {
        // The batched refill is a pure transport change: the reference
        // sequence is identical for every batch size.
        let w = by_name("milc").unwrap();
        let mut a = Pull::new(w.source_with_batch("b", 1));
        let mut b = Pull::new(w.source_with_batch("b", SOURCE_BATCH));
        let mut c = Pull::new(w.source_with_batch("b", 7));
        for _ in 0..500 {
            let ra = a.take_one();
            assert_eq!(ra, b.take_one());
            assert_eq!(ra, c.take_one());
        }
    }

    #[test]
    fn fill_appends_a_full_batch() {
        let w = by_name("gups").unwrap();
        let mut s = w.source("fb");
        let mut buf = Vec::new();
        assert_eq!(s.fill(&mut buf), SOURCE_BATCH);
        assert_eq!(buf.len(), SOURCE_BATCH);
        // fill *appends*: a second call must not clobber the first batch.
        assert_eq!(s.fill(&mut buf), SOURCE_BATCH);
        assert_eq!(buf.len(), 2 * SOURCE_BATCH);
    }

    #[test]
    fn null_source_is_exhausted() {
        let mut s = NullSource;
        let mut buf = Vec::new();
        assert_eq!(s.fill(&mut buf), 0);
        assert!(buf.is_empty());
    }

    #[test]
    fn mean_gap_tracks_mpki() {
        let w = by_name("stream.copy").unwrap(); // mpki 45 -> gap ~22
        let mut t = pull(&w, "x");
        let n = 20_000;
        let total: u64 = (0..n).map(|_| t.take_one().gap_insts as u64).sum();
        let mean = total as f64 / n as f64;
        let expect = 1000.0 / w.mpki;
        assert!((mean - expect).abs() < expect * 0.1,
                "mean gap {mean}, expected {expect}");
    }

    #[test]
    fn addresses_stay_in_footprint() {
        for w in suite() {
            let mut t = pull(&w, "bounds");
            for _ in 0..1000 {
                let r = t.take_one();
                assert!(r.addr < w.footprint, "{} addr {}", w.name, r.addr);
            }
        }
    }

    #[test]
    fn phased_pattern_schedules_bursts() {
        let mk = |repeat| WorkloadSpec {
            name: "ph",
            pattern: Pattern::Phased { active_refs: 10,
                                       idle_gap: 1_000_000, repeat },
            mpki: 40.0,
            write_ratio: 0.0,
            footprint: 64 * MB,
        };
        // repeat: one idle reference closes each 11-reference period.
        let mut t = pull(&mk(true), "x");
        let idle = (0..110).filter(|_| t.take_one().gap_insts == 1_000_000)
            .count();
        assert_eq!(idle, 10);
        // front-loaded: everything after the burst is idle.
        let mut t = pull(&mk(false), "x");
        for i in 0..40 {
            let g = t.take_one().gap_insts;
            if i < 10 {
                assert!(g < 1_000_000, "ref {i} in the burst got gap {g}");
            } else {
                assert_eq!(g, 1_000_000, "ref {i} past the burst");
            }
        }
    }

    #[test]
    fn stream_is_sequential_random_is_not() {
        let mut st = pull(&by_name("libquantum").unwrap(), "s");
        let mut seq = 0;
        let mut prev = st.take_one().addr;
        for _ in 0..100 {
            let a = st.take_one().addr;
            if a == prev + 64 {
                seq += 1;
            }
            prev = a;
        }
        assert!(seq > 90, "stream sequentiality {seq}/100");

        let mut rnd = pull(&by_name("gups").unwrap(), "r");
        let mut seq = 0;
        let mut prev = rnd.take_one().addr;
        for _ in 0..100 {
            let a = rnd.take_one().addr;
            if a == prev + 64 {
                seq += 1;
            }
            prev = a;
        }
        assert!(seq < 5, "random sequentiality {seq}/100");
    }

    #[test]
    fn mixed_stream_half_is_contiguous_and_confined() {
        // Regression: `pos` used to wrap at footprint/2 while the address
        // was reduced `% footprint`, so the "sequential" half could alias
        // the random half and split a run across the footprint boundary.
        // Now it must stay inside one contiguous line-aligned
        // half-footprint window and walk it monotonically between wraps.
        let spec = WorkloadSpec {
            name: "mixfix",
            pattern: Pattern::Mixed,
            mpki: 20.0,
            write_ratio: 0.2,
            footprint: MB, // small so the window wraps within the test
        };
        let rng = Rng::from_label("mixfix/window");
        let mut g = Generator::new(spec.clone(), rng);
        let half = spec.footprint / 2;
        let base = g.streams[0].base;
        assert_eq!(base % 64, 0, "window is line-aligned");
        assert!(base + half <= spec.footprint,
                "window [{},{}) exceeds the footprint", base, base + half);
        let mut prev_pos = g.streams[0].pos;
        let mut streamed = 0u64;
        let mut wraps = 0u64;
        for _ in 0..60_000 {
            let r = g.gen_ref();
            let pos = g.streams[0].pos;
            if pos == prev_pos {
                continue; // random-half reference: stream state untouched
            }
            streamed += 1;
            assert_eq!(r.addr, base + pos, "streamed addr confined to window");
            assert!(r.addr < spec.footprint);
            if pos == 0 {
                assert_eq!(prev_pos, half - 64, "wrap only from the window end");
                wraps += 1;
            } else {
                assert_eq!(pos, prev_pos + 64,
                           "stream must be monotone-contiguous between wraps");
            }
            prev_pos = pos;
        }
        assert!(streamed > 20_000, "stream half starved: {streamed}");
        assert!(wraps >= 1, "window never wrapped — test footprint too big");
    }
}
