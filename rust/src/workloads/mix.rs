//! Named multi-programmed mixes for the paper's multi-core evaluation
//! (§6/Fig 6-7): each mix pairs a memory-intensive workload with a
//! non-intensive one, two cores each, so every mix keeps memory pressure
//! while mixing intensity classes. The metric for a mix is the *weighted
//! speedup* (`SystemStats::weighted_speedup`): the mean over cores of the
//! per-core IPC ratio against the baseline run — insensitive to one core
//! dominating the throughput sum.

use super::{by_name, NamedSource, WorkloadSpec};

/// How many cores a mix populates (two copies of each member).
pub const MIX_CORES: usize = 4;

/// One named multi-programmed mix.
#[derive(Debug, Clone)]
pub struct MixSpec {
    /// `"<intensive>+<non-intensive>"`.
    pub name: String,
    /// One entry per core ([`MIX_CORES`] entries: intensive twice, then
    /// non-intensive twice).
    pub members: Vec<WorkloadSpec>,
}

impl MixSpec {
    fn pair(intensive: &str, light: &str) -> Self {
        let hi = by_name(intensive)
            .unwrap_or_else(|| panic!("unknown workload `{intensive}`"));
        let lo = by_name(light)
            .unwrap_or_else(|| panic!("unknown workload `{light}`"));
        assert!(hi.memory_intensive(), "{intensive} is not memory-intensive");
        assert!(!lo.memory_intensive(), "{light} is memory-intensive");
        MixSpec {
            name: format!("{intensive}+{light}"),
            members: vec![hi.clone(), hi, lo.clone(), lo],
        }
    }

    /// Mean member MPKI (the mix's x-axis position in the Fig-6 table).
    pub fn mpki(&self) -> f64 {
        self.members.iter().map(|w| w.mpki).sum::<f64>()
            / self.members.len() as f64
    }

    /// Instantiate one source per core, seeded
    /// `"<seed_label>/core<k>"` per member (deterministic per mix, seed
    /// and core slot).
    pub fn sources(&self, seed_label: &str) -> Vec<NamedSource> {
        self.members
            .iter()
            .enumerate()
            .map(|(k, w)| w.named_source(&format!("{seed_label}/core{k}")))
            .collect()
    }
}

/// The named mix pool: 10 intensive × non-intensive pairings spanning the
/// suite's pattern families (streaming, random, pointer-chase, mixed) on
/// the intensive side.
pub fn suite() -> Vec<MixSpec> {
    [
        ("stream.copy", "povray"),
        ("gups", "h264ref"),
        ("mcf", "gobmk"),
        ("lbm", "namd"),
        ("milc", "perlbench"),
        ("libquantum", "bzip2"),
        ("tpcc64", "sjeng"),
        ("omnetpp", "gamess"),
        ("soplex", "calculix"),
        ("rand.read", "hmmer"),
    ]
    .into_iter()
    .map(|(hi, lo)| MixSpec::pair(hi, lo))
    .collect()
}

/// Look a mix up by its `"<intensive>+<non-intensive>"` name.
pub fn mix_by_name(name: &str) -> Option<MixSpec> {
    suite().into_iter().find(|m| m.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_pool_is_named_and_paired() {
        let mixes = suite();
        assert!(mixes.len() >= 8, "paper-style eval needs >= 8 mixes");
        let mut names: Vec<&str> = mixes.iter().map(|m| m.name.as_str())
            .collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), mixes.len(), "mix names must be unique");
        for m in &mixes {
            assert_eq!(m.members.len(), MIX_CORES);
            let hi = m.members.iter()
                .filter(|w| w.memory_intensive())
                .count();
            assert_eq!(hi, MIX_CORES / 2,
                       "{}: intensive/non-intensive halves", m.name);
            assert_eq!(m.name,
                       format!("{}+{}", m.members[0].name, m.members[2].name));
        }
    }

    #[test]
    fn mix_lookup_and_sources() {
        let m = mix_by_name("mcf+gobmk").unwrap();
        assert!(mix_by_name("nope+nothing").is_none());
        let srcs = m.sources("t");
        assert_eq!(srcs.len(), MIX_CORES);
        assert_eq!(srcs[0].name, "mcf");
        assert_eq!(srcs[3].name, "gobmk");
        assert_eq!(srcs[1].seed, "t/core1");
        assert_eq!(srcs[0].footprint, m.members[0].footprint);
        // Two copies of the same member must not share a seed (their
        // address streams diverge immediately).
        assert_ne!(srcs[0].seed, srcs[1].seed);
    }

    #[test]
    fn mix_mpki_is_member_mean() {
        let m = mix_by_name("gups+h264ref").unwrap();
        let expect = (35.0 + 35.0 + 0.8 + 0.8) / 4.0;
        assert!((m.mpki() - expect).abs() < 1e-12);
    }
}
