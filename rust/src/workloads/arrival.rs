//! Open-loop arrival processes (DESIGN.md §16).
//!
//! Closed-loop cores retire instructions and issue the next reference
//! only when the previous one allows it, so the offered request rate is
//! a *consequence* of memory latency. A latency-vs-throughput curve —
//! the knee where queueing delay diverges, and the p99/p99.9 tail below
//! it — needs the opposite: an *offered load* in requests per
//! controller cycle that arrives regardless of how the memory system is
//! doing. An [`ArrivalSource`] provides exactly that by wrapping any
//! workload's [`RequestSource`] and rewriting each reference's
//! `gap_insts` field to carry an inter-arrival gap in **controller
//! cycles** drawn from an arrival process (the address / read-write
//! pattern of the inner workload is kept untouched, so "gups under
//! Poisson load" stresses the same rows and banks as closed-loop gups).
//!
//! Three processes cover the shapes that matter for tail latency:
//!
//! * [`ArrivalKind::Poisson`] — memoryless: i.i.d. exponential gaps
//!   with mean `1/load`. The M/D/c-ish baseline.
//! * [`ArrivalKind::Bursty`] — a two-state Markov-modulated process:
//!   after every arrival the state flips with probability `1 - stay`,
//!   and the on-state draws gaps `burst` times shorter than the
//!   off-state. Long-run rate is still `load`; the clustering is what
//!   drives p99.9 away from p50 at equal mean load.
//! * [`ArrivalKind::Diurnal`] — a deterministic sinusoid modulating the
//!   instantaneous rate, `r(t) = load * (1 + amp * sin(2πt/period))`,
//!   evaluated at the stream's own accumulated arrival time (a scaled
//!   stand-in for day-scale load swings; `period` is in controller
//!   cycles). Exercises slow load drift across thermal epochs.
//!
//! Every draw comes from the source's own [`Rng`] labelled
//! `arrival/{kind}/{seed}` — deliberately *without* the load in the
//! label, so sweeping load over one seed reuses the same underlying
//! uniform stream (common random numbers: the Poisson gap at load L is
//! exactly the load-L' gap scaled by L'/L, which smooths knee searches).
//! The stream is timing-independent — gaps depend only on the rng and
//! the process, never on simulated state — which is what lets K lockstep
//! systems share ONE generation through `eval::lockstep::SharedSourceSet`
//! (the `repro eval load` sweep) and what keeps `run`/`run_fast`
//! bit-identical (DESIGN.md §16 sketches the proof).

use crate::util::rng::Rng;
use crate::workloads::{MemRef, NamedSource, RequestSource, WorkloadSpec};

/// Default off/on mean-gap ratio for [`ArrivalKind::Bursty`].
pub const BURST_RATIO: f64 = 8.0;
/// Default per-arrival probability of *staying* in the current burst
/// state (mean run length 32 arrivals).
pub const BURST_STAY: f64 = 1.0 - 1.0 / 32.0;
/// Default modulation amplitude for [`ArrivalKind::Diurnal`].
pub const DIURNAL_AMP: f64 = 0.8;
/// Default modulation period for [`ArrivalKind::Diurnal`], in
/// controller cycles (64 thermal epochs).
pub const DIURNAL_PERIOD: u64 = 1 << 16;

/// The arrival-process family. See the module docs for semantics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalKind {
    Poisson,
    Bursty { burst: f64, stay: f64 },
    Diurnal { amp: f64, period: u64 },
}

impl ArrivalKind {
    /// CLI name → kind with the module-level default parameters.
    pub fn by_name(name: &str) -> Option<ArrivalKind> {
        match name {
            "poisson" => Some(ArrivalKind::Poisson),
            "bursty" => Some(ArrivalKind::Bursty {
                burst: BURST_RATIO,
                stay: BURST_STAY,
            }),
            "diurnal" => Some(ArrivalKind::Diurnal {
                amp: DIURNAL_AMP,
                period: DIURNAL_PERIOD,
            }),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::Bursty { .. } => "bursty",
            ArrivalKind::Diurnal { .. } => "diurnal",
        }
    }
}

/// An offered-load point: `load` requests per controller cycle (per
/// core), shaped by `kind`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArrivalSpec {
    pub kind: ArrivalKind,
    pub load: f64,
}

impl ArrivalSpec {
    /// The open-loop source: `w`'s address/read-write stream with gaps
    /// redrawn from this arrival process. Feed it to a core running in
    /// open-loop mode ([`crate::mem::System::set_open_loop`]) — the
    /// closed-loop core would misread the gaps as instruction counts.
    pub fn source(&self, w: &WorkloadSpec, seed_label: &str)
                  -> Box<dyn RequestSource> {
        assert!(self.load > 0.0 && self.load.is_finite(),
                "offered load must be positive, got {}", self.load);
        Box::new(ArrivalSource {
            inner: w.source(seed_label),
            rng: Rng::from_label(
                &format!("arrival/{}/{seed_label}", self.kind.name())),
            kind: self.kind,
            load: self.load,
            on_state: true,
            t: 0,
        })
    }

    /// [`Self::source`] with the stream metadata the lockstep sharing
    /// and trace machinery key on.
    pub fn named_source(&self, w: &WorkloadSpec, seed_label: &str)
                        -> NamedSource {
        NamedSource {
            name: format!("{}+{}", w.name, self.kind.name()),
            seed: seed_label.to_string(),
            footprint: w.footprint,
            source: self.source(w, seed_label),
        }
    }
}

/// Gap-rewriting wrapper: the inner workload supplies addresses and
/// read/write flags, the arrival process supplies timing.
struct ArrivalSource {
    inner: Box<dyn RequestSource>,
    rng: Rng,
    kind: ArrivalKind,
    load: f64,
    /// Bursty: current modulation state (on = short gaps).
    on_state: bool,
    /// Diurnal: accumulated arrival time (sum of emitted gaps).
    t: u64,
}

impl ArrivalSource {
    /// Exponential gap with the given mean, rounded to whole cycles and
    /// clamped exactly as the closed-loop `Generator::gap` clamps (so a
    /// pathological draw cannot overflow downstream u64 arithmetic).
    fn exp_gap(&mut self, mean: f64) -> u32 {
        let u = self.rng.f64().max(1e-12);
        (-mean * u.ln()).round().min(1e7) as u32
    }

    fn draw_gap(&mut self) -> u32 {
        match self.kind {
            ArrivalKind::Poisson => {
                let mean = 1.0 / self.load;
                self.exp_gap(mean)
            }
            ArrivalKind::Bursty { burst, stay } => {
                if !self.rng.chance(stay) {
                    self.on_state = !self.on_state;
                }
                // Means chosen so the two states average to 1/load:
                // g_on + g_off = 2/load with g_off = burst * g_on.
                let g_on = (2.0 / self.load) / (1.0 + burst);
                let mean = if self.on_state { g_on } else { g_on * burst };
                self.exp_gap(mean)
            }
            ArrivalKind::Diurnal { amp, period } => {
                let phase = (self.t % period) as f64 / period as f64;
                let rate = self.load
                    * (1.0 + amp * (2.0 * std::f64::consts::PI * phase).sin());
                // amp < 1 keeps the rate positive; clamp defensively so
                // a user-supplied amp >= 1 degrades to huge gaps rather
                // than NaN/negative means.
                let mean = 1.0 / rate.max(self.load * 1e-3);
                self.exp_gap(mean)
            }
        }
    }
}

impl RequestSource for ArrivalSource {
    fn fill(&mut self, out: &mut Vec<MemRef>) -> usize {
        let start = out.len();
        let n = self.inner.fill(out);
        for r in &mut out[start..] {
            let gap = self.draw_gap();
            r.gap_insts = gap; // reinterpreted: controller cycles
            r.dependent = false; // open-loop has no dependence semantics
            self.t += gap as u64;
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::by_name;

    fn pull(spec: &ArrivalSpec, seed: &str, n: usize) -> Vec<MemRef> {
        let w = by_name("gups").unwrap();
        let mut src = spec.source(&w, seed);
        let mut out = Vec::new();
        while out.len() < n {
            if src.fill(&mut out) == 0 {
                break;
            }
        }
        out.truncate(n);
        out
    }

    #[test]
    fn poisson_mean_gap_tracks_offered_load() {
        for load in [0.01, 0.1, 0.5] {
            let spec = ArrivalSpec { kind: ArrivalKind::Poisson, load };
            let refs = pull(&spec, "t", 20_000);
            let mean: f64 = refs.iter().map(|r| r.gap_insts as f64)
                .sum::<f64>() / refs.len() as f64;
            let want = 1.0 / load;
            assert!((mean - want).abs() / want < 0.05,
                    "load {load}: mean gap {mean} vs {want}");
        }
    }

    #[test]
    fn bursty_and_diurnal_hold_the_long_run_rate() {
        for name in ["bursty", "diurnal"] {
            let kind = ArrivalKind::by_name(name).unwrap();
            let spec = ArrivalSpec { kind, load: 0.1 };
            let refs = pull(&spec, "t", 50_000);
            let mean: f64 = refs.iter().map(|r| r.gap_insts as f64)
                .sum::<f64>() / refs.len() as f64;
            assert!((mean - 10.0).abs() < 1.0,
                    "{name}: mean gap {mean} vs 10");
        }
    }

    #[test]
    fn bursty_clusters_more_than_poisson() {
        // Squared coefficient of variation of gaps: Poisson ≈ 1, the
        // two-state MMPP must sit clearly above it at equal mean load.
        let scv = |kind: ArrivalKind| {
            let refs = pull(&ArrivalSpec { kind, load: 0.1 }, "t", 50_000);
            let gaps: Vec<f64> =
                refs.iter().map(|r| r.gap_insts as f64).collect();
            let m = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let v = gaps.iter().map(|g| (g - m) * (g - m)).sum::<f64>()
                / gaps.len() as f64;
            v / (m * m)
        };
        let poisson = scv(ArrivalKind::Poisson);
        let bursty = scv(ArrivalKind::by_name("bursty").unwrap());
        assert!(poisson < 1.3, "poisson scv {poisson}");
        assert!(bursty > 1.5 * poisson,
                "bursty scv {bursty} vs poisson {poisson}");
    }

    #[test]
    fn addresses_are_the_inner_workloads_regardless_of_kind() {
        // The arrival process must only touch timing: same seed, same
        // workload → identical address / read-write sequences across
        // kinds (and across loads).
        let base: Vec<(u64, bool)> =
            pull(&ArrivalSpec { kind: ArrivalKind::Poisson, load: 0.1 },
                 "s", 2_000)
                .iter().map(|r| (r.addr, r.is_write)).collect();
        for (name, load) in [("poisson", 0.5), ("bursty", 0.1),
                             ("diurnal", 0.1)] {
            let kind = ArrivalKind::by_name(name).unwrap();
            let got: Vec<(u64, bool)> =
                pull(&ArrivalSpec { kind, load }, "s", 2_000)
                    .iter().map(|r| (r.addr, r.is_write)).collect();
            assert_eq!(base, got, "{name}@{load} changed the access stream");
        }
    }

    #[test]
    fn seeds_are_deterministic_and_distinct() {
        let spec = ArrivalSpec { kind: ArrivalKind::Poisson, load: 0.05 };
        let a = pull(&spec, "seed-a", 1_000);
        let b = pull(&spec, "seed-a", 1_000);
        let c = pull(&spec, "seed-b", 1_000);
        assert_eq!(a, b, "same seed must replay bit-identically");
        assert_ne!(a, c, "different seeds must differ");
    }
}
