//! The profiling orchestrator — the software stand-in for the SoftMC
//! FPGA testing platform: refresh-interval sweeps, timing-parameter
//! sweeps, the per-DIMM characterization battery, and the repeatability
//! analysis. See DESIGN.md §2/§8 (and §7 for the vectorized engine the
//! sweeps probe through).

pub mod refresh;
pub mod repeat;
pub mod results;
pub mod sweep;

pub use refresh::{profile_refresh, RefreshProfile, SAFETY_MARGIN_MS};
pub use repeat::{repeatability, RepeatabilityReport};
pub use results::{profile_dimm, profile_dimm_regions, profile_dimm_seeded,
                  summarize, verify_timings, DimmProfile, PopulationSummary,
                  RegionDimmProfile, RegionProfile, TimingProfile};
pub use sweep::{sweep, sweep_bank, sweep_ecc, sweep_exhaustive, sweep_par,
                sweep_seeded, sweep_with, sweep_with_seed, BestCombo,
                FrontierPoint, SweepOpts, SweepResult, TestKind};
