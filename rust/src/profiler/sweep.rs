//! Timing-parameter sweeps (Fig 2b/2c, Fig 3c/3d).
//!
//! For a DIMM at a given temperature and (safe) refresh interval, find the
//! acceptable (error-free) timing combinations and the most-reduced one.
//!
//! The pass/fail surface is monotone in every parameter, so instead of the
//! full grid (|tRCD| x |tRAS| x |tRP| ~ 1k combos) we run a *wave-parallel
//! bisection*: for every (tRCD, tRP) pair the minimum acceptable tRAS (read)
//! or tWR (write) is found by binary search, and all active pairs probe
//! their midpoint in one backend batch per wave. This turns ~1.6k combo
//! evaluations into ~6 batched calls — the optimization that makes the
//! PJRT path (per-call dispatch cost) fast; see EXPERIMENTS.md §Perf.
//! `repro ablate sweep-exhaustive` cross-checks it against the full grid.

use anyhow::Result;

use crate::model::{CellArrays, Combo};
use crate::runtime::ProfilingBackend;
use crate::timing::{SweepGrids, TimingParams};

/// Which test chain drives the sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TestKind {
    Read,  // tRCD x tRAS x tRP, tWR at standard
    Write, // tRCD x tWR x tRP, tRAS at standard
}

/// Minimum acceptable third parameter for one (tRCD, tRP) pair.
#[derive(Debug, Clone, Copy)]
pub struct FrontierPoint {
    pub trcd_ns: f64,
    pub trp_ns: f64,
    /// Minimum error-free tRAS (read) / tWR (write); `None` if the pair is
    /// infeasible even with the standard third parameter.
    pub min_third_ns: Option<f64>,
}

/// The most-reduced acceptable combination for one test kind.
#[derive(Debug, Clone, Copy)]
pub struct BestCombo {
    pub trcd_ns: f64,
    pub third_ns: f64, // tRAS for read, tWR for write
    pub trp_ns: f64,
    pub sum_ns: f64,
    /// Fractional reduction of the sum vs. the standard sum.
    pub reduction: f64,
}

/// Full sweep result for one (DIMM, temperature, refresh interval).
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub kind: TestKind,
    pub temp_c: f64,
    pub tref_ms: f64,
    pub frontier: Vec<FrontierPoint>,
    pub best: Option<BestCombo>,
}

fn combo_for(kind: TestKind, trcd: f64, third: f64, trp: f64, tref: f64,
             temp: f64) -> Combo {
    let std = TimingParams::ddr3_standard();
    match kind {
        TestKind::Read => Combo {
            trcd: trcd as f32,
            tras: third as f32,
            twr: std.twr_ns as f32,
            trp: trp as f32,
            tref_ms: tref as f32,
            temp_c: temp as f32,
        },
        TestKind::Write => Combo {
            trcd: trcd as f32,
            tras: std.tras_ns as f32,
            twr: third as f32,
            trp: trp as f32,
            tref_ms: tref as f32,
            temp_c: temp as f32,
        },
    }
}

fn errors_of(kind: TestKind, out: &crate::model::ProfileOutput, k: usize) -> f64 {
    match kind {
        TestKind::Read => out.read_errors(k),
        TestKind::Write => out.write_errors(k),
    }
}

/// Third-parameter grid (descending: index 0 = most relaxed) legal for a
/// given tRCD.
fn third_grid(kind: TestKind, grids: &SweepGrids, trcd: f64) -> Vec<f64> {
    match kind {
        TestKind::Read => grids
            .tras
            .iter()
            .cloned()
            .filter(|t| SweepGrids::tras_legal(trcd, *t))
            .collect(),
        TestKind::Write => grids.twr.clone(),
    }
}

/// Pass criterion for a combo: inspects the profiling output at index `k`.
/// The standard sweep requires zero errors module-wide; the bank-granular
/// extension (paper §5.2 "future work") requires zero errors in one bank;
/// the ECC extension (§9.2) tolerates a correctable error budget.
pub type PassFn<'a> = &'a dyn Fn(&crate::model::ProfileOutput, usize) -> bool;

/// Wave-parallel bisection over all (tRCD, tRP) pairs with the standard
/// module-wide zero-error criterion.
pub fn sweep(backend: &mut dyn ProfilingBackend, arrays: &CellArrays,
             kind: TestKind, temp_c: f64, tref_ms: f64) -> Result<SweepResult> {
    let pass: PassFn = &|out, k| errors_of(kind, out, k) == 0.0;
    sweep_with(backend, arrays, kind, temp_c, tref_ms, pass)
}

/// Sweep for a single bank: a combo is acceptable iff that bank is
/// error-free (other banks may err — they run their own timings).
pub fn sweep_bank(backend: &mut dyn ProfilingBackend, arrays: &CellArrays,
                  kind: TestKind, temp_c: f64, tref_ms: f64, bank: usize)
                  -> Result<SweepResult> {
    let pass: PassFn = &|out, k| match kind {
        TestKind::Read => out.bank_errors_read(k)[bank] == 0.0,
        TestKind::Write => out.bank_errors_write(k)[bank] == 0.0,
    };
    sweep_with(backend, arrays, kind, temp_c, tref_ms, pass)
}

/// Sweep with an ECC budget: up to `budget` failing cells module-wide are
/// considered correctable (§9.2's "error correction to enable even lower
/// latency"; DIVA-DRAM explores the same direction).
pub fn sweep_ecc(backend: &mut dyn ProfilingBackend, arrays: &CellArrays,
                 kind: TestKind, temp_c: f64, tref_ms: f64, budget: f64)
                 -> Result<SweepResult> {
    let pass: PassFn = &|out, k| errors_of(kind, out, k) <= budget;
    sweep_with(backend, arrays, kind, temp_c, tref_ms, pass)
}

/// Wave-parallel bisection over all (tRCD, tRP) pairs under an arbitrary
/// monotone pass criterion.
pub fn sweep_with(backend: &mut dyn ProfilingBackend, arrays: &CellArrays,
                  kind: TestKind, temp_c: f64, tref_ms: f64,
                  pass: PassFn) -> Result<SweepResult> {
    let grids = SweepGrids::standard();

    struct Pair {
        trcd: f64,
        trp: f64,
        grid: Vec<f64>, // descending third-parameter grid
        lo: usize,      // largest index known error-free
        hi: usize,      // search upper bound (inclusive)
        feasible: bool,
    }

    let mut pairs: Vec<Pair> = Vec::new();
    for &trcd in &grids.trcd {
        for &trp in &grids.trp {
            let grid = third_grid(kind, &grids, trcd);
            if grid.is_empty() {
                continue;
            }
            let hi = grid.len() - 1;
            pairs.push(Pair { trcd, trp, grid, lo: 0, hi, feasible: false });
        }
    }

    // Wave 0: most-relaxed third parameter decides feasibility.
    let combos: Vec<Combo> = pairs
        .iter()
        .map(|p| combo_for(kind, p.trcd, p.grid[0], p.trp, tref_ms, temp_c))
        .collect();
    let out = backend.profile(arrays, &combos)?;
    for (i, p) in pairs.iter_mut().enumerate() {
        p.feasible = pass(&out, i);
    }

    // Bisection waves: probe mid = ceil((lo+hi)/2) for every unconverged
    // feasible pair; error-free probes advance lo, failing probes pull hi.
    loop {
        let active: Vec<usize> = pairs
            .iter()
            .enumerate()
            .filter(|(_, p)| p.feasible && p.lo < p.hi)
            .map(|(i, _)| i)
            .collect();
        if active.is_empty() {
            break;
        }
        let combos: Vec<Combo> = active
            .iter()
            .map(|&i| {
                let p = &pairs[i];
                let mid = (p.lo + p.hi + 1) / 2;
                combo_for(kind, p.trcd, p.grid[mid], p.trp, tref_ms, temp_c)
            })
            .collect();
        let out = backend.profile(arrays, &combos)?;
        for (j, &i) in active.iter().enumerate() {
            let p = &mut pairs[i];
            let mid = (p.lo + p.hi + 1) / 2;
            if pass(&out, j) {
                p.lo = mid;
            } else {
                p.hi = mid - 1;
            }
        }
    }

    let frontier: Vec<FrontierPoint> = pairs
        .iter()
        .map(|p| FrontierPoint {
            trcd_ns: p.trcd,
            trp_ns: p.trp,
            min_third_ns: p.feasible.then(|| p.grid[p.lo]),
        })
        .collect();

    let std = TimingParams::ddr3_standard();
    let std_sum = match kind {
        TestKind::Read => std.read_sum_ns(),
        TestKind::Write => std.write_sum_ns(),
    };
    let best = frontier
        .iter()
        .filter_map(|f| {
            f.min_third_ns.map(|third| BestCombo {
                trcd_ns: f.trcd_ns,
                third_ns: third,
                trp_ns: f.trp_ns,
                sum_ns: f.trcd_ns + third + f.trp_ns,
                reduction: 1.0 - (f.trcd_ns + third + f.trp_ns) / std_sum,
            })
        })
        .min_by(|a, b| {
            // Tie-break equal sums toward lower tRCD, then lower tRP —
            // the balance the paper's per-parameter averages reflect.
            (a.sum_ns, a.trcd_ns, a.trp_ns)
                .partial_cmp(&(b.sum_ns, b.trcd_ns, b.trp_ns))
                .unwrap()
        });

    Ok(SweepResult { kind, temp_c, tref_ms, frontier, best })
}

/// Exhaustive full-grid sweep (the ablation oracle for the bisection).
pub fn sweep_exhaustive(backend: &mut dyn ProfilingBackend,
                        arrays: &CellArrays, kind: TestKind, temp_c: f64,
                        tref_ms: f64) -> Result<SweepResult> {
    let grids = SweepGrids::standard();
    let mut frontier = Vec::new();
    for &trcd in &grids.trcd {
        for &trp in &grids.trp {
            let grid = third_grid(kind, &grids, trcd);
            if grid.is_empty() {
                continue;
            }
            let combos: Vec<Combo> = grid
                .iter()
                .map(|&t| combo_for(kind, trcd, t, trp, tref_ms, temp_c))
                .collect();
            let out = backend.profile(arrays, &combos)?;
            // grid is descending; acceptance is a prefix by monotonicity.
            let mut min_third = None;
            for (i, &t) in grid.iter().enumerate() {
                if errors_of(kind, &out, i) == 0.0 {
                    min_third = Some(t);
                } else {
                    break;
                }
            }
            frontier.push(FrontierPoint { trcd_ns: trcd, trp_ns: trp,
                                          min_third_ns: min_third });
        }
    }
    let std = TimingParams::ddr3_standard();
    let std_sum = match kind {
        TestKind::Read => std.read_sum_ns(),
        TestKind::Write => std.write_sum_ns(),
    };
    let best = frontier
        .iter()
        .filter_map(|f| {
            f.min_third_ns.map(|third| BestCombo {
                trcd_ns: f.trcd_ns,
                third_ns: third,
                trp_ns: f.trp_ns,
                sum_ns: f.trcd_ns + third + f.trp_ns,
                reduction: 1.0 - (f.trcd_ns + third + f.trp_ns) / std_sum,
            })
        })
        .min_by(|a, b| {
            // Tie-break equal sums toward lower tRCD, then lower tRP —
            // the balance the paper's per-parameter averages reflect.
            (a.sum_ns, a.trcd_ns, a.trp_ns)
                .partial_cmp(&(b.sum_ns, b.trcd_ns, b.trp_ns))
                .unwrap()
        });
    Ok(SweepResult { kind, temp_c, tref_ms, frontier, best })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params;
    use crate::population::generate_dimm;
    use crate::runtime::NativeBackend;

    #[test]
    fn bisection_matches_exhaustive() {
        let d = generate_dimm(2, 64, params());
        let mut b = NativeBackend::new();
        for kind in [TestKind::Read, TestKind::Write] {
            let fast = sweep(&mut b, &d.arrays, kind, 85.0, 200.0).unwrap();
            let full =
                sweep_exhaustive(&mut b, &d.arrays, kind, 85.0, 200.0).unwrap();
            assert_eq!(fast.frontier.len(), full.frontier.len());
            for (a, o) in fast.frontier.iter().zip(&full.frontier) {
                assert_eq!(a.trcd_ns, o.trcd_ns);
                assert_eq!(a.trp_ns, o.trp_ns);
                assert_eq!(a.min_third_ns, o.min_third_ns,
                           "pair ({}, {})", a.trcd_ns, a.trp_ns);
            }
        }
    }

    #[test]
    fn standard_combo_is_always_acceptable() {
        let d = generate_dimm(4, 64, params());
        let mut b = NativeBackend::new();
        let r = sweep(&mut b, &d.arrays, TestKind::Read, 85.0, 64.0).unwrap();
        // The (std tRCD, std tRP) pair must be feasible with min tRAS <= 35.
        let std_pair = r
            .frontier
            .iter()
            .find(|f| f.trcd_ns == 13.75 && f.trp_ns == 13.75)
            .unwrap();
        assert!(std_pair.min_third_ns.is_some());
        assert!(r.best.is_some());
        assert!(r.best.unwrap().reduction >= 0.0);
    }

    #[test]
    fn cooler_allows_more_reduction() {
        let d = generate_dimm(6, 64, params());
        let mut b = NativeBackend::new();
        let hot = sweep(&mut b, &d.arrays, TestKind::Write, 85.0, 152.0)
            .unwrap().best.unwrap();
        let cool = sweep(&mut b, &d.arrays, TestKind::Write, 55.0, 152.0)
            .unwrap().best.unwrap();
        assert!(cool.reduction >= hot.reduction - 1e-9,
                "cool {} vs hot {}", cool.reduction, hot.reduction);
    }

    #[test]
    fn frontier_is_monotone_in_trcd() {
        // A more relaxed tRCD can only relax the tRAS requirement.
        let d = generate_dimm(8, 64, params());
        let mut b = NativeBackend::new();
        let r = sweep(&mut b, &d.arrays, TestKind::Read, 85.0, 200.0).unwrap();
        for f1 in &r.frontier {
            for f2 in &r.frontier {
                if f1.trp_ns == f2.trp_ns && f1.trcd_ns < f2.trcd_ns {
                    if let (Some(a), Some(b_)) =
                        (f1.min_third_ns, f2.min_third_ns)
                    {
                        // note: legality floor rises with tRCD, so compare
                        // only when both are above both floors
                        let floor = f2.trcd_ns
                            + params().floors.tras_over_trcd_ns;
                        if a > floor && b_ > floor {
                            assert!(a >= b_ - 1e-9);
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod ext_tests {
    use super::*;
    use crate::model::params;
    use crate::population::generate_dimm;
    use crate::runtime::NativeBackend;

    #[test]
    fn bank_sweeps_dominate_the_module_sweep() {
        let d = generate_dimm(5, 128, params());
        let mut b = NativeBackend::new();
        let module = sweep(&mut b, &d.arrays, TestKind::Read, 85.0, 200.0)
            .unwrap().best.unwrap();
        for bank in 0..d.arrays.banks {
            let bb = sweep_bank(&mut b, &d.arrays, TestKind::Read, 85.0,
                                200.0, bank).unwrap().best.unwrap();
            assert!(bb.sum_ns <= module.sum_ns + 1e-9,
                    "bank {bank} slower than module");
        }
        // The module equals its worst bank (min over banks of reduction).
        let worst = (0..d.arrays.banks)
            .map(|bank| {
                sweep_bank(&mut b, &d.arrays, TestKind::Read, 85.0, 200.0,
                           bank).unwrap().best.unwrap().sum_ns
            })
            .fold(0.0f64, f64::max);
        assert!((worst - module.sum_ns).abs() < 1e-9);
    }

    #[test]
    fn ecc_budget_is_monotone() {
        let d = generate_dimm(5, 128, params());
        let mut b = NativeBackend::new();
        let mut last = f64::MAX;
        for budget in [0.0, 2.0, 32.0] {
            let s = sweep_ecc(&mut b, &d.arrays, TestKind::Read, 85.0, 256.0,
                              budget).unwrap().best.unwrap();
            assert!(s.sum_ns <= last + 1e-9);
            last = s.sum_ns;
        }
    }

    #[test]
    fn ecc_zero_budget_equals_plain_sweep() {
        let d = generate_dimm(9, 128, params());
        let mut b = NativeBackend::new();
        let plain = sweep(&mut b, &d.arrays, TestKind::Write, 85.0, 200.0)
            .unwrap().best.unwrap();
        let ecc0 = sweep_ecc(&mut b, &d.arrays, TestKind::Write, 85.0, 200.0,
                             0.0).unwrap().best.unwrap();
        assert_eq!(plain.sum_ns, ecc0.sum_ns);
    }
}
