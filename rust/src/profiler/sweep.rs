//! Timing-parameter sweeps (Fig 2b/2c, Fig 3c/3d).
//!
//! For a DIMM at a given temperature and (safe) refresh interval, find the
//! acceptable (error-free) timing combinations and the most-reduced one.
//!
//! The pass/fail surface is monotone in every parameter, so instead of the
//! full grid (|tRCD| x |tRAS| x |tRP| ~ 1k combos) we run a *wave-parallel
//! search*: for every (tRCD, tRP) pair the minimum acceptable tRAS (read)
//! or tWR (write) is found by a galloping binary search, and all active
//! pairs probe their next index in one backend batch per wave. Probes go
//! through `ProfilingBackend::pass_probe`, so an engine with an early-exit
//! probe (the SIMD backend's weakest-first screen) decides failing combos
//! in O(weak prefix) instead of O(cells).
//!
//! Sweeps can be *warm-started* from a neighboring (temperature, tREF)
//! point's frontier (`sweep_seeded` / `sweep_with_seed`): each pair's
//! search then opens at the seed index and gallops outward, converging in
//! ~2 waves when the frontier barely moves (the surface is monotone across
//! the temperature and refresh axes too). Seeding is an *initial guess*,
//! not an assumption — every boundary is re-proven by probes, so a seed
//! from either direction (or a wrong one) changes only the wave count,
//! never the result. `repro ablate sweep-exhaustive` and
//! `tests/runtime_simd_xcheck.rs` cross-check against the full grid.

use anyhow::Result;

use crate::model::{CellArrays, Combo};
use crate::runtime::{PassCriterion, ProbeKind, ProfilingBackend};
use crate::timing::{SweepGrids, TimingParams};

/// Which test chain drives the sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TestKind {
    Read,  // tRCD x tRAS x tRP, tWR at standard
    Write, // tRCD x tWR x tRP, tRAS at standard
}

fn probe_kind(kind: TestKind) -> ProbeKind {
    match kind {
        TestKind::Read => ProbeKind::Read,
        TestKind::Write => ProbeKind::Write,
    }
}

/// Minimum acceptable third parameter for one (tRCD, tRP) pair.
#[derive(Debug, Clone, Copy)]
pub struct FrontierPoint {
    pub trcd_ns: f64,
    pub trp_ns: f64,
    /// Minimum error-free tRAS (read) / tWR (write); `None` if the pair is
    /// infeasible even with the standard third parameter.
    pub min_third_ns: Option<f64>,
}

/// The most-reduced acceptable combination for one test kind.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BestCombo {
    pub trcd_ns: f64,
    pub third_ns: f64, // tRAS for read, tWR for write
    pub trp_ns: f64,
    pub sum_ns: f64,
    /// Fractional reduction of the sum vs. the standard sum.
    pub reduction: f64,
}

/// Full sweep result for one (DIMM, temperature, refresh interval).
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub kind: TestKind,
    pub temp_c: f64,
    pub tref_ms: f64,
    pub frontier: Vec<FrontierPoint>,
    pub best: Option<BestCombo>,
}

fn combo_for(kind: TestKind, trcd: f64, third: f64, trp: f64, tref: f64,
             temp: f64) -> Combo {
    let std = TimingParams::ddr3_standard();
    match kind {
        TestKind::Read => Combo {
            trcd: trcd as f32,
            tras: third as f32,
            twr: std.twr_ns as f32,
            trp: trp as f32,
            tref_ms: tref as f32,
            temp_c: temp as f32,
        },
        TestKind::Write => Combo {
            trcd: trcd as f32,
            tras: std.tras_ns as f32,
            twr: third as f32,
            trp: trp as f32,
            tref_ms: tref as f32,
            temp_c: temp as f32,
        },
    }
}

fn errors_of(kind: TestKind, out: &crate::model::ProfileOutput, k: usize) -> f64 {
    match kind {
        TestKind::Read => out.read_errors(k),
        TestKind::Write => out.write_errors(k),
    }
}

/// Third-parameter grid (descending: index 0 = most relaxed) legal for a
/// given tRCD.
fn third_grid(kind: TestKind, grids: &SweepGrids, trcd: f64) -> Vec<f64> {
    match kind {
        TestKind::Read => grids
            .tras
            .iter()
            .cloned()
            .filter(|t| SweepGrids::tras_legal(trcd, *t))
            .collect(),
        TestKind::Write => grids.twr.clone(),
    }
}

/// Search state of one (tRCD, tRP) pair over its descending third-
/// parameter grid. Invariant: the acceptance boundary (largest passing
/// index; passes form a prefix by monotonicity) lies strictly between
/// `lo` (largest index *proven* to pass) and `hi` (smallest index
/// *proven* to fail). Every probe lands in the open unknown interval, so
/// each wave strictly shrinks it and the search terminates with the same
/// boundary the exhaustive scan finds — regardless of the seed.
#[derive(Debug, Clone)]
struct PairState {
    trcd: f64,
    trp: f64,
    grid: Vec<f64>, // descending third-parameter grid
    seed: Option<usize>,
    lo: Option<usize>, // largest index confirmed passing
    hi: Option<usize>, // smallest index confirmed failing
    step: usize,       // galloping stride
}

impl PairState {
    fn new(trcd: f64, trp: f64, grid: Vec<f64>, seed: Option<usize>) -> Self {
        // Seeded pairs expect the boundary nearby: gallop from stride 1.
        // Cold pairs start at the feasibility probe (index 0) and then
        // jump straight to the far end, degenerating to plain bisection.
        let step = if seed.is_some() { 1 } else { grid.len().max(1) };
        PairState { trcd, trp, grid, seed, lo: None, hi: None, step }
    }

    fn done(&self) -> bool {
        match (self.lo, self.hi) {
            (_, Some(0)) => true, // infeasible: most relaxed value fails
            (Some(p), _) if p + 1 == self.grid.len() => true,
            (Some(p), Some(f)) => p + 1 == f,
            _ => false,
        }
    }

    fn next_probe(&self) -> usize {
        match (self.lo, self.hi) {
            (None, None) => self.seed.unwrap_or(0),
            (Some(p), None) => (p + self.step).min(self.grid.len() - 1),
            (None, Some(f)) => f - self.step.min(f),
            (Some(p), Some(f)) => (p + f) / 2,
        }
    }

    fn update(&mut self, probe: usize, pass: bool) {
        // The stride doubles only once galloping has started (i.e. not on
        // the opening seed/feasibility probe), so a seeded pair whose
        // boundary did not move converges in exactly two waves: probe the
        // seed, then its immediate neighbor.
        let galloping = self.lo.is_some() || self.hi.is_some();
        if pass {
            self.lo = Some(self.lo.map_or(probe, |p| p.max(probe)));
        } else {
            self.hi = Some(self.hi.map_or(probe, |f| f.min(probe)));
        }
        if galloping {
            self.step *= 2;
        }
    }

    fn min_third(&self) -> Option<f64> {
        if self.hi == Some(0) {
            return None;
        }
        self.lo.map(|p| self.grid[p])
    }
}

/// Build the (tRCD, tRP) pair lattice, seeding each pair's search from a
/// previous frontier when one is given (pairs the seed found infeasible,
/// or whose seed value is not on this pair's grid, start cold).
fn build_pairs(kind: TestKind, seed: Option<&SweepResult>) -> Vec<PairState> {
    let grids = SweepGrids::standard();
    let mut pairs = Vec::new();
    for &trcd in &grids.trcd {
        for &trp in &grids.trp {
            let grid = third_grid(kind, &grids, trcd);
            if grid.is_empty() {
                continue;
            }
            let seed_idx = seed.and_then(|s| {
                s.frontier
                    .iter()
                    .find(|f| f.trcd_ns == trcd && f.trp_ns == trp)
                    .and_then(|f| f.min_third_ns)
                    .and_then(|third| grid.iter().position(|t| *t == third))
            });
            pairs.push(PairState::new(trcd, trp, grid, seed_idx));
        }
    }
    pairs
}

/// Run the batched wave loop until every pair's boundary is proven.
fn solve_pairs(backend: &mut dyn ProfilingBackend, arrays: &CellArrays,
               kind: TestKind, temp_c: f64, tref_ms: f64,
               criterion: PassCriterion, pairs: &mut [PairState])
               -> Result<()> {
    let pk = probe_kind(kind);
    loop {
        let active: Vec<usize> = pairs
            .iter()
            .enumerate()
            .filter(|(_, p)| !p.done())
            .map(|(i, _)| i)
            .collect();
        if active.is_empty() {
            return Ok(());
        }
        let probes: Vec<usize> =
            active.iter().map(|&i| pairs[i].next_probe()).collect();
        let combos: Vec<Combo> = active
            .iter()
            .zip(&probes)
            .map(|(&i, &pr)| {
                let p = &pairs[i];
                combo_for(kind, p.trcd, p.grid[pr], p.trp, tref_ms, temp_c)
            })
            .collect();
        let pass = backend.pass_probe(arrays, &combos, pk, criterion)?;
        for ((&i, &pr), ok) in active.iter().zip(&probes).zip(pass) {
            pairs[i].update(pr, ok);
        }
    }
}

/// Pick the most-reduced acceptable combination off a frontier.
fn best_of(kind: TestKind, frontier: &[FrontierPoint]) -> Option<BestCombo> {
    let std = TimingParams::ddr3_standard();
    let std_sum = match kind {
        TestKind::Read => std.read_sum_ns(),
        TestKind::Write => std.write_sum_ns(),
    };
    frontier
        .iter()
        .filter_map(|f| {
            f.min_third_ns.map(|third| BestCombo {
                trcd_ns: f.trcd_ns,
                third_ns: third,
                trp_ns: f.trp_ns,
                sum_ns: f.trcd_ns + third + f.trp_ns,
                reduction: 1.0 - (f.trcd_ns + third + f.trp_ns) / std_sum,
            })
        })
        .min_by(|a, b| {
            // Tie-break equal sums toward lower tRCD, then lower tRP —
            // the balance the paper's per-parameter averages reflect.
            (a.sum_ns, a.trcd_ns, a.trp_ns)
                .partial_cmp(&(b.sum_ns, b.trcd_ns, b.trp_ns))
                .unwrap()
        })
}

fn finalize(kind: TestKind, temp_c: f64, tref_ms: f64,
            pairs: &[PairState]) -> SweepResult {
    let frontier: Vec<FrontierPoint> = pairs
        .iter()
        .map(|p| FrontierPoint {
            trcd_ns: p.trcd,
            trp_ns: p.trp,
            min_third_ns: p.min_third(),
        })
        .collect();
    let best = best_of(kind, &frontier);
    SweepResult { kind, temp_c, tref_ms, frontier, best }
}

/// Wave-parallel search over all (tRCD, tRP) pairs with the standard
/// module-wide zero-error criterion.
pub fn sweep(backend: &mut dyn ProfilingBackend, arrays: &CellArrays,
             kind: TestKind, temp_c: f64, tref_ms: f64) -> Result<SweepResult> {
    sweep_with_seed(backend, arrays, kind, temp_c, tref_ms,
                    PassCriterion::Module { budget: 0.0 }, None)
}

/// [`sweep`] warm-started from a neighboring (temperature, tREF) point's
/// frontier — the campaign fast path (the result is seed-independent).
pub fn sweep_seeded(backend: &mut dyn ProfilingBackend, arrays: &CellArrays,
                    kind: TestKind, temp_c: f64, tref_ms: f64,
                    seed: Option<&SweepResult>) -> Result<SweepResult> {
    sweep_with_seed(backend, arrays, kind, temp_c, tref_ms,
                    PassCriterion::Module { budget: 0.0 }, seed)
}

/// Sweep for a single bank: a combo is acceptable iff that bank is
/// error-free (other banks may err — they run their own timings).
pub fn sweep_bank(backend: &mut dyn ProfilingBackend, arrays: &CellArrays,
                  kind: TestKind, temp_c: f64, tref_ms: f64, bank: usize)
                  -> Result<SweepResult> {
    sweep_with_seed(backend, arrays, kind, temp_c, tref_ms,
                    PassCriterion::Bank { bank }, None)
}

/// Sweep with an ECC budget: up to `budget` failing cells module-wide are
/// considered correctable (§9.2's "error correction to enable even lower
/// latency"; DIVA-DRAM explores the same direction).
pub fn sweep_ecc(backend: &mut dyn ProfilingBackend, arrays: &CellArrays,
                 kind: TestKind, temp_c: f64, tref_ms: f64, budget: f64)
                 -> Result<SweepResult> {
    sweep_with_seed(backend, arrays, kind, temp_c, tref_ms,
                    PassCriterion::Module { budget }, None)
}

/// Wave-parallel search over all (tRCD, tRP) pairs under an arbitrary
/// monotone pass criterion.
pub fn sweep_with(backend: &mut dyn ProfilingBackend, arrays: &CellArrays,
                  kind: TestKind, temp_c: f64, tref_ms: f64,
                  criterion: PassCriterion) -> Result<SweepResult> {
    sweep_with_seed(backend, arrays, kind, temp_c, tref_ms, criterion, None)
}

/// [`sweep_with`] plus an optional warm-start seed.
pub fn sweep_with_seed(backend: &mut dyn ProfilingBackend,
                       arrays: &CellArrays, kind: TestKind, temp_c: f64,
                       tref_ms: f64, criterion: PassCriterion,
                       seed: Option<&SweepResult>) -> Result<SweepResult> {
    let mut pairs = build_pairs(kind, seed);
    solve_pairs(backend, arrays, kind, temp_c, tref_ms, criterion,
                &mut pairs)?;
    Ok(finalize(kind, temp_c, tref_ms, &pairs))
}

/// Pass criterion + optional warm-start seed for [`sweep_par`].
#[derive(Clone, Copy, Default)]
pub struct SweepOpts<'a> {
    pub criterion: PassCriterion,
    pub seed: Option<&'a SweepResult>,
}

/// Parallel sweep: independent (tRCD, tRP) pairs are partitioned into
/// contiguous chunks and their probe waves driven through `exec::Pool`,
/// one worker-owned backend per chunk. The frontier is identical for any
/// job count (pairs never interact; chunks are reassembled in order).
pub fn sweep_par<F>(make_backend: F, arrays: &CellArrays, kind: TestKind,
                    temp_c: f64, tref_ms: f64, opts: SweepOpts,
                    jobs: usize) -> Result<SweepResult>
where
    F: Fn() -> Box<dyn ProfilingBackend> + Sync,
{
    let SweepOpts { criterion, seed } = opts;
    let pairs = build_pairs(kind, seed);
    if pairs.is_empty() {
        // Degenerate grids (every pair's third grid empty): match the
        // sequential path's empty frontier instead of panicking in
        // `chunks(0)`.
        return Ok(finalize(kind, temp_c, tref_ms, &pairs));
    }
    let jobs = jobs.max(1).min(pairs.len());
    let chunk = pairs.len().div_ceil(jobs);
    let chunks: Vec<&[PairState]> = pairs.chunks(chunk).collect();
    let solved = crate::exec::Pool::new(jobs).try_run_init(
        chunks.len(),
        &make_backend,
        |b, i| {
            let mut ch = chunks[i].to_vec();
            solve_pairs(b.as_mut(), arrays, kind, temp_c, tref_ms, criterion,
                        &mut ch)?;
            Ok(ch)
        },
    )?;
    let pairs: Vec<PairState> = solved.into_iter().flatten().collect();
    Ok(finalize(kind, temp_c, tref_ms, &pairs))
}

/// Exhaustive full-grid sweep (the ablation oracle for the wave search).
/// Each pair's third-parameter grid is evaluated in small chunks and the
/// scan stops at the chunk containing the first failure — combos past it
/// are never evaluated (acceptance is a prefix by monotonicity, so the
/// oracle answer is unchanged).
pub fn sweep_exhaustive(backend: &mut dyn ProfilingBackend,
                        arrays: &CellArrays, kind: TestKind, temp_c: f64,
                        tref_ms: f64) -> Result<SweepResult> {
    const CHUNK: usize = 8;
    let grids = SweepGrids::standard();
    let mut frontier = Vec::new();
    for &trcd in &grids.trcd {
        for &trp in &grids.trp {
            let grid = third_grid(kind, &grids, trcd);
            if grid.is_empty() {
                continue;
            }
            let mut min_third = None;
            'chunks: for chunk in grid.chunks(CHUNK) {
                let combos: Vec<Combo> = chunk
                    .iter()
                    .map(|&t| combo_for(kind, trcd, t, trp, tref_ms, temp_c))
                    .collect();
                let out = backend.profile(arrays, &combos)?;
                // grid is descending; acceptance is a prefix.
                for (i, &t) in chunk.iter().enumerate() {
                    if errors_of(kind, &out, i) == 0.0 {
                        min_third = Some(t);
                    } else {
                        break 'chunks;
                    }
                }
            }
            frontier.push(FrontierPoint { trcd_ns: trcd, trp_ns: trp,
                                          min_third_ns: min_third });
        }
    }
    let best = best_of(kind, &frontier);
    Ok(SweepResult { kind, temp_c, tref_ms, frontier, best })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params;
    use crate::population::generate_dimm;
    use crate::runtime::{NativeBackend, SimdBackend};

    #[test]
    fn bisection_matches_exhaustive() {
        let d = generate_dimm(2, 64, params());
        let mut b = NativeBackend::new();
        for kind in [TestKind::Read, TestKind::Write] {
            let fast = sweep(&mut b, &d.arrays, kind, 85.0, 200.0).unwrap();
            let full =
                sweep_exhaustive(&mut b, &d.arrays, kind, 85.0, 200.0).unwrap();
            assert_eq!(fast.frontier.len(), full.frontier.len());
            for (a, o) in fast.frontier.iter().zip(&full.frontier) {
                assert_eq!(a.trcd_ns, o.trcd_ns);
                assert_eq!(a.trp_ns, o.trp_ns);
                assert_eq!(a.min_third_ns, o.min_third_ns,
                           "pair ({}, {})", a.trcd_ns, a.trp_ns);
            }
        }
    }

    #[test]
    fn seeded_sweep_matches_cold_in_both_directions() {
        // Warm starts are a wave-count optimization only: seeding from the
        // easier point, the harder point, or the wrong chain must all
        // reproduce the cold frontier exactly.
        let d = generate_dimm(3, 64, params());
        let mut b = SimdBackend::new();
        let hot = sweep(&mut b, &d.arrays, TestKind::Read, 85.0, 200.0)
            .unwrap();
        let cool = sweep(&mut b, &d.arrays, TestKind::Read, 55.0, 200.0)
            .unwrap();
        let check = |got: &SweepResult, want: &SweepResult| {
            for (a, o) in got.frontier.iter().zip(&want.frontier) {
                assert_eq!(a.min_third_ns, o.min_third_ns,
                           "pair ({}, {})", a.trcd_ns, a.trp_ns);
            }
        };
        let warm_cool = sweep_seeded(&mut b, &d.arrays, TestKind::Read, 55.0,
                                     200.0, Some(&hot)).unwrap();
        check(&warm_cool, &cool);
        let warm_hot = sweep_seeded(&mut b, &d.arrays, TestKind::Read, 85.0,
                                    200.0, Some(&cool)).unwrap();
        check(&warm_hot, &hot);
        // Cross-kind seed degrades to a cold start, never a wrong result.
        let wseed = sweep(&mut b, &d.arrays, TestKind::Write, 85.0, 200.0)
            .unwrap();
        let cross = sweep_seeded(&mut b, &d.arrays, TestKind::Read, 85.0,
                                 200.0, Some(&wseed)).unwrap();
        check(&cross, &hot);
    }

    #[test]
    fn sweep_par_matches_sequential_for_any_job_count() {
        let d = generate_dimm(4, 64, params());
        let mut b = SimdBackend::new();
        let seq = sweep(&mut b, &d.arrays, TestKind::Read, 85.0, 200.0)
            .unwrap();
        let factory = || -> Box<dyn ProfilingBackend> {
            Box::new(SimdBackend::new())
        };
        for jobs in [1usize, 3, 16] {
            let par = sweep_par(&factory, &d.arrays, TestKind::Read, 85.0,
                                200.0, SweepOpts::default(), jobs).unwrap();
            assert_eq!(par.frontier.len(), seq.frontier.len());
            for (a, o) in par.frontier.iter().zip(&seq.frontier) {
                assert_eq!(a.min_third_ns, o.min_third_ns);
            }
            assert_eq!(par.best.unwrap().sum_ns, seq.best.unwrap().sum_ns);
        }
        // Seeded + parallel (the §7.1 ladder configuration).
        let cold55 = sweep(&mut b, &d.arrays, TestKind::Read, 55.0, 200.0)
            .unwrap();
        let warm_par = sweep_par(
            &factory, &d.arrays, TestKind::Read, 55.0, 200.0,
            SweepOpts { criterion: PassCriterion::default(),
                        seed: Some(&seq) },
            3,
        )
        .unwrap();
        for (a, o) in warm_par.frontier.iter().zip(&cold55.frontier) {
            assert_eq!(a.min_third_ns, o.min_third_ns);
        }
    }

    #[test]
    fn standard_combo_is_always_acceptable() {
        let d = generate_dimm(4, 64, params());
        let mut b = NativeBackend::new();
        let r = sweep(&mut b, &d.arrays, TestKind::Read, 85.0, 64.0).unwrap();
        // The (std tRCD, std tRP) pair must be feasible with min tRAS <= 35.
        let std_pair = r
            .frontier
            .iter()
            .find(|f| f.trcd_ns == 13.75 && f.trp_ns == 13.75)
            .unwrap();
        assert!(std_pair.min_third_ns.is_some());
        assert!(r.best.is_some());
        assert!(r.best.unwrap().reduction >= 0.0);
    }

    #[test]
    fn cooler_allows_more_reduction() {
        let d = generate_dimm(6, 64, params());
        let mut b = NativeBackend::new();
        let hot = sweep(&mut b, &d.arrays, TestKind::Write, 85.0, 152.0)
            .unwrap().best.unwrap();
        let cool = sweep(&mut b, &d.arrays, TestKind::Write, 55.0, 152.0)
            .unwrap().best.unwrap();
        assert!(cool.reduction >= hot.reduction - 1e-9,
                "cool {} vs hot {}", cool.reduction, hot.reduction);
    }

    #[test]
    fn frontier_is_monotone_in_trcd() {
        // A more relaxed tRCD can only relax the tRAS requirement.
        let d = generate_dimm(8, 64, params());
        let mut b = NativeBackend::new();
        let r = sweep(&mut b, &d.arrays, TestKind::Read, 85.0, 200.0).unwrap();
        for f1 in &r.frontier {
            for f2 in &r.frontier {
                if f1.trp_ns == f2.trp_ns && f1.trcd_ns < f2.trcd_ns {
                    if let (Some(a), Some(b_)) =
                        (f1.min_third_ns, f2.min_third_ns)
                    {
                        // note: legality floor rises with tRCD, so compare
                        // only when both are above both floors
                        let floor = f2.trcd_ns
                            + params().floors.tras_over_trcd_ns;
                        if a > floor && b_ > floor {
                            assert!(a >= b_ - 1e-9);
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod ext_tests {
    use super::*;
    use crate::model::params;
    use crate::population::generate_dimm;
    use crate::runtime::NativeBackend;

    #[test]
    fn bank_sweeps_dominate_the_module_sweep() {
        let d = generate_dimm(5, 128, params());
        let mut b = NativeBackend::new();
        let module = sweep(&mut b, &d.arrays, TestKind::Read, 85.0, 200.0)
            .unwrap().best.unwrap();
        for bank in 0..d.arrays.banks {
            let bb = sweep_bank(&mut b, &d.arrays, TestKind::Read, 85.0,
                                200.0, bank).unwrap().best.unwrap();
            assert!(bb.sum_ns <= module.sum_ns + 1e-9,
                    "bank {bank} slower than module");
        }
        // The module equals its worst bank (min over banks of reduction).
        let worst = (0..d.arrays.banks)
            .map(|bank| {
                sweep_bank(&mut b, &d.arrays, TestKind::Read, 85.0, 200.0,
                           bank).unwrap().best.unwrap().sum_ns
            })
            .fold(0.0f64, f64::max);
        assert!((worst - module.sum_ns).abs() < 1e-9);
    }

    #[test]
    fn ecc_budget_is_monotone() {
        let d = generate_dimm(5, 128, params());
        let mut b = NativeBackend::new();
        let mut last = f64::MAX;
        for budget in [0.0, 2.0, 32.0] {
            let s = sweep_ecc(&mut b, &d.arrays, TestKind::Read, 85.0, 256.0,
                              budget).unwrap().best.unwrap();
            assert!(s.sum_ns <= last + 1e-9);
            last = s.sum_ns;
        }
    }

    #[test]
    fn ecc_zero_budget_equals_plain_sweep() {
        let d = generate_dimm(9, 128, params());
        let mut b = NativeBackend::new();
        let plain = sweep(&mut b, &d.arrays, TestKind::Write, 85.0, 200.0)
            .unwrap().best.unwrap();
        let ecc0 = sweep_ecc(&mut b, &d.arrays, TestKind::Write, 85.0, 200.0,
                             0.0).unwrap().best.unwrap();
        assert_eq!(plain.sum_ns, ecc0.sum_ns);
    }
}
