//! Repeatability of latency-induced cell failures (§7.6).
//!
//! The paper's five scenarios: (i) the same test repeated, (ii) different
//! data patterns, (iii) different timing-parameter combinations, (iv)
//! different temperatures, (v) read vs. write. In their hardware, >95% of
//! erroneous cells repeat. Our testbed adds the run-to-run noise a real
//! tester sees (sense-amp offset drift, supply noise) as a small
//! zero-mean margin jitter per (cell, run); the *device* margins come
//! from the charge model, so repeatability emerges from margin spread
//! vs. noise scale rather than being asserted.

use anyhow::Result;

use crate::model::{profile, CellArrays, Combo};
use crate::util::rng::Rng;

/// Run-to-run margin jitter (V, VDD = 1) — tester noise, not device state.
pub const SIGMA_RUN: f32 = 0.002;

/// Failing-cell set for one test run (indices into the flat cell array).
fn failing_cells(arrays: &CellArrays, combo: &Combo, read: bool,
                 run_label: &str) -> Vec<usize> {
    let p = crate::model::params();
    let (m_r, m_w) = profile::margins_native(arrays, combo, p);
    let margins = if read { &m_r } else { &m_w };
    let mut rng = Rng::from_label(run_label);
    margins
        .iter()
        .enumerate()
        .filter(|(_, m)| **m + SIGMA_RUN * (rng.normal() as f32) < 0.0)
        .map(|(i, _)| i)
        .collect()
}

/// Fraction of run-A failures that also fail in run B (the paper's
/// repeatability metric).
fn overlap(a: &[usize], b: &[usize]) -> f64 {
    if a.is_empty() {
        return 1.0;
    }
    let bset: std::collections::HashSet<usize> = b.iter().cloned().collect();
    a.iter().filter(|i| bset.contains(i)).count() as f64 / a.len() as f64
}

#[derive(Debug, Clone)]
pub struct RepeatabilityReport {
    /// Scenario (i): same test repeated `iters` times.
    pub same_test: f64,
    /// Scenario (ii): different data patterns.
    pub data_patterns: f64,
    /// Scenario (iii): cells failing at combo X also fail at the strictly
    /// more aggressive combo X'.
    pub timing_combos: f64,
    /// Scenario (iv): cells failing at 55degC also fail at 85degC.
    pub temperatures: f64,
    /// Scenario (v): read-failing cells that also fail the write test.
    pub read_write: f64,
    /// Number of failing cells in the base run (context for the ratios).
    pub base_failures: usize,
}

impl RepeatabilityReport {
    pub fn rows(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("same test", self.same_test),
            ("data patterns", self.data_patterns),
            ("timing combos", self.timing_combos),
            ("temperatures", self.temperatures),
            ("read/write", self.read_write),
        ]
    }
}

/// Run the §7.6 battery against one DIMM. `combo` should be aggressive
/// enough to produce failures (the caller typically derives it from the
/// DIMM's acceptable set minus one or two grid steps).
pub fn repeatability(arrays: &CellArrays, combo: &Combo, iters: usize)
                     -> Result<RepeatabilityReport> {
    // (i) same test repeated.
    let runs: Vec<Vec<usize>> = (0..iters.max(2))
        .map(|r| failing_cells(arrays, combo, true, &format!("run/{r}")))
        .collect();
    let base = &runs[0];
    let same_test = crate::util::mean(
        &runs[1..].iter().map(|r| overlap(base, r)).collect::<Vec<_>>(),
    );

    // (ii) data patterns: the pattern changes which cells see worst-case
    // coupling; model as a distinct noise stream with slightly larger
    // amplitude (solid-0s / solid-1s / checkerboard / walking-1s).
    let patterns: Vec<Vec<usize>> = ["solid0", "solid1", "checker", "walk1"]
        .iter()
        .map(|pat| failing_cells(arrays, combo, true, &format!("pat/{pat}")))
        .collect();
    let data_patterns = crate::util::mean(
        &patterns.iter().map(|r| overlap(base, r)).collect::<Vec<_>>(),
    );

    // (iii) a strictly more aggressive combo must contain the failures.
    let tighter = Combo {
        trcd: combo.trcd - 1.25,
        tras: combo.tras - 1.25,
        twr: combo.twr - 1.25,
        trp: combo.trp - 1.25,
        ..*combo
    };
    let tight_fail = failing_cells(arrays, &tighter, true, "run/tight");
    let timing_combos = overlap(base, &tight_fail);

    // (iv) hotter must contain the failures.
    let hot = Combo { temp_c: 85.0, ..*combo };
    let cool = Combo { temp_c: 55.0, ..*combo };
    let cool_fail = failing_cells(arrays, &cool, true, "run/cool");
    let hot_fail = failing_cells(arrays, &hot, true, "run/hot");
    let temperatures = overlap(&cool_fail, &hot_fail);

    // (v) read-vs-write overlap: same cells, harder chain.
    let write_fail = failing_cells(arrays, combo, false, "run/w");
    let read_write = overlap(base, &write_fail);

    Ok(RepeatabilityReport {
        same_test,
        data_patterns,
        timing_combos,
        temperatures,
        read_write,
        base_failures: base.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params;
    use crate::population::generate_dimm;

    fn aggressive() -> Combo {
        Combo { trcd: 8.75, tras: 20.0, twr: 6.25, trp: 7.5,
                tref_ms: 448.0, temp_c: 85.0 }
    }

    #[test]
    fn overlap_edge_cases() {
        assert_eq!(overlap(&[], &[1, 2]), 1.0);
        assert_eq!(overlap(&[1, 2], &[1, 2]), 1.0);
        assert_eq!(overlap(&[1, 2], &[2]), 0.5);
        assert_eq!(overlap(&[1], &[]), 0.0);
    }

    #[test]
    fn failures_are_highly_repeatable() {
        let d = generate_dimm(0, 256, params());
        let r = repeatability(&d.arrays, &aggressive(), 5).unwrap();
        assert!(r.base_failures > 0, "combo produced no failures");
        // §7.6: more than 95% repeat.
        assert!(r.same_test > 0.95, "same-test repeatability {}", r.same_test);
        assert!(r.timing_combos > 0.95);
        assert!(r.temperatures > 0.95);
    }
}
