//! Retention / refresh-interval profiling (Fig 2a, Fig 3a/3b).
//!
//! Sweeps the refresh interval at standard timings and a fixed temperature
//! and finds the maximum error-free interval at module, bank and chip
//! granularity; the *safe* interval subtracts the sweep increment (8 ms),
//! exactly as §5.1 defines it.

use anyhow::Result;

use crate::model::{CellArrays, Combo};
use crate::runtime::ProfilingBackend;
use crate::timing::{SweepGrids, TimingParams};

/// Sweep increment and safety margin (ms) from §5.1.
pub const SAFETY_MARGIN_MS: f64 = 8.0;

#[derive(Debug, Clone, PartialEq)]
pub struct RefreshProfile {
    pub temp_c: f64,
    /// Maximum error-free refresh interval (ms) across the module.
    pub module_max_read_ms: f64,
    pub module_max_write_ms: f64,
    /// Per-bank maxima (length = banks).
    pub bank_max_read_ms: Vec<f64>,
    pub bank_max_write_ms: Vec<f64>,
    /// Per-chip maxima (length = chips).
    pub chip_max_read_ms: Vec<f64>,
    pub chip_max_write_ms: Vec<f64>,
    /// True if the module never erred within the sweep range (maxima are
    /// then lower bounds at the top of the grid).
    pub saturated_read: bool,
    pub saturated_write: bool,
}

impl RefreshProfile {
    /// §5.1: safe interval = maximum error-free interval − 8 ms.
    pub fn safe_read_ms(&self) -> f64 {
        (self.module_max_read_ms - SAFETY_MARGIN_MS).max(SAFETY_MARGIN_MS)
    }

    pub fn safe_write_ms(&self) -> f64 {
        (self.module_max_write_ms - SAFETY_MARGIN_MS).max(SAFETY_MARGIN_MS)
    }
}

/// Largest grid value whose error count is zero, honoring retention
/// monotonicity (the first failing interval closes the window).
fn max_error_free(grid: &[f64], errs: &[f64]) -> (f64, bool) {
    debug_assert_eq!(grid.len(), errs.len());
    let mut best = grid[0];
    for (t, e) in grid.iter().zip(errs) {
        if *e == 0.0 {
            best = *t;
        } else {
            break;
        }
    }
    let saturated = errs.iter().all(|e| *e == 0.0);
    (best, saturated)
}

/// Run the refresh sweep at standard timings.
pub fn profile_refresh(backend: &mut dyn ProfilingBackend,
                       arrays: &CellArrays, temp_c: f64)
                       -> Result<RefreshProfile> {
    let grids = SweepGrids::standard();
    let std = TimingParams::ddr3_standard();
    let combos: Vec<Combo> = grids
        .tref_ms
        .iter()
        .map(|t| Combo {
            trcd: std.trcd_ns as f32,
            tras: std.tras_ns as f32,
            twr: std.twr_ns as f32,
            trp: std.trp_ns as f32,
            tref_ms: *t as f32,
            temp_c: temp_c as f32,
        })
        .collect();
    let out = backend.profile(arrays, &combos)?;

    let k = combos.len();
    let tot_r: Vec<f64> = (0..k).map(|i| out.read_errors(i)).collect();
    let tot_w: Vec<f64> = (0..k).map(|i| out.write_errors(i)).collect();
    let (module_max_read_ms, saturated_read) =
        max_error_free(&grids.tref_ms, &tot_r);
    let (module_max_write_ms, saturated_write) =
        max_error_free(&grids.tref_ms, &tot_w);

    let per_unit = |unit_errs: &dyn Fn(usize) -> Vec<f64>, units: usize| {
        (0..units)
            .map(|u| {
                let errs: Vec<f64> =
                    (0..k).map(|ki| unit_errs(ki)[u]).collect();
                max_error_free(&grids.tref_ms, &errs).0
            })
            .collect::<Vec<f64>>()
    };

    Ok(RefreshProfile {
        temp_c,
        module_max_read_ms,
        module_max_write_ms,
        bank_max_read_ms: per_unit(&|ki| out.bank_errors_read(ki), out.banks),
        bank_max_write_ms: per_unit(&|ki| out.bank_errors_write(ki), out.banks),
        chip_max_read_ms: per_unit(&|ki| out.chip_errors_read(ki), out.chips),
        chip_max_write_ms: per_unit(&|ki| out.chip_errors_write(ki), out.chips),
        saturated_read,
        saturated_write,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params;
    use crate::population::generate_dimm;
    use crate::runtime::NativeBackend;

    #[test]
    fn max_error_free_stops_at_first_failure() {
        let grid = [64.0, 72.0, 80.0, 88.0];
        // Non-monotone noise after the first failure must not re-open.
        let (t, sat) = max_error_free(&grid, &[0.0, 0.0, 3.0, 0.0]);
        assert_eq!(t, 72.0);
        assert!(!sat);
        let (t, sat) = max_error_free(&grid, &[0.0, 0.0, 0.0, 0.0]);
        assert_eq!(t, 88.0);
        assert!(sat);
    }

    #[test]
    fn module_max_is_min_of_units() {
        let d = generate_dimm(0, 128, params());
        let mut b = NativeBackend::new();
        let p = profile_refresh(&mut b, &d.arrays, 85.0).unwrap();
        // The module is as weak as its weakest bank and weakest chip.
        let bank_min = p.bank_max_read_ms.iter().cloned().fold(f64::MAX, f64::min);
        let chip_min = p.chip_max_read_ms.iter().cloned().fold(f64::MAX, f64::min);
        assert_eq!(p.module_max_read_ms, bank_min.min(chip_min));
        assert!(p.module_max_read_ms >= 64.0);
        assert!(p.safe_read_ms() <= p.module_max_read_ms);
    }

    #[test]
    fn standard_interval_is_error_free() {
        // DDR3 compliance: every module passes at 64 ms / 85 degC.
        for id in [0usize, 5, 9] {
            let d = generate_dimm(id, 128, params());
            let mut b = NativeBackend::new();
            let p = profile_refresh(&mut b, &d.arrays, 85.0).unwrap();
            assert!(p.module_max_read_ms >= 64.0, "dimm {id}");
            assert!(p.module_max_write_ms >= 64.0, "dimm {id}");
        }
    }

    #[test]
    fn cooler_retains_longer() {
        let d = generate_dimm(1, 128, params());
        let mut b = NativeBackend::new();
        let hot = profile_refresh(&mut b, &d.arrays, 85.0).unwrap();
        let cool = profile_refresh(&mut b, &d.arrays, 55.0).unwrap();
        assert!(cool.module_max_read_ms >= hot.module_max_read_ms);
        assert!(cool.module_max_write_ms >= hot.module_max_write_ms);
    }
}
