//! Per-DIMM profiling results and the population campaign (Fig 3).

use anyhow::Result;

use crate::model::Combo;
use crate::population::Dimm;
use crate::profiler::refresh::{profile_refresh, RefreshProfile};
use crate::profiler::sweep::{sweep_seeded, BestCombo, SweepResult, TestKind};
use crate::runtime::ProfilingBackend;
use crate::timing::TimingParams;
use crate::util;

/// Everything AL-DRAM needs to know about one DIMM at one temperature.
#[derive(Debug, Clone, PartialEq)]
pub struct TimingProfile {
    pub temp_c: f64,
    pub tref_read_ms: f64,
    pub tref_write_ms: f64,
    pub read: BestCombo,  // tRCD / tRAS / tRP from the read test
    pub write: BestCombo, // tRCD / tWR  / tRP from the write test
}

impl TimingProfile {
    /// One operational timing set per (DIMM, temperature): the memory
    /// controller needs a single tRCD/tRP that satisfies both test chains,
    /// so take the conservative (larger) of the two; tRAS comes from the
    /// read test and tWR from the write test.
    pub fn combined(&self) -> TimingParams {
        let std = TimingParams::ddr3_standard();
        std.with_core(
            self.read.trcd_ns.max(self.write.trcd_ns),
            self.read.third_ns,
            self.write.third_ns,
            self.read.trp_ns.max(self.write.trp_ns),
        )
    }

    /// Per-parameter fractional reductions [tRCD, tRAS, tWR, tRP] of the
    /// combined set vs. the standard (the Fig 3c/3d companion numbers).
    pub fn param_reductions(&self) -> [f64; 4] {
        let std = TimingParams::ddr3_standard();
        let c = self.combined();
        [
            1.0 - c.trcd_ns / std.trcd_ns,
            1.0 - c.tras_ns / std.tras_ns,
            1.0 - c.twr_ns / std.twr_ns,
            1.0 - c.trp_ns / std.trp_ns,
        ]
    }
}

/// Full characterization of one DIMM: the Fig 2 battery.
#[derive(Debug, Clone, PartialEq)]
pub struct DimmProfile {
    pub id: usize,
    pub vendor: String,
    /// Refresh sweep at the worst-case temperature (Fig 2a).
    pub refresh85: RefreshProfile,
    /// Timing sweeps at each temperature using the safe refresh intervals.
    pub at85: TimingProfile,
    pub at55: TimingProfile,
}

/// Profile one DIMM end to end: refresh sweep at 85degC to establish the
/// safe intervals, then timing sweeps at 85degC and 55degC (§5.1's
/// procedure, applied per-DIMM as in §5.2). The 55degC sweeps are
/// warm-started from the 85degC frontiers — the pass surface is monotone
/// across temperature, so each pair's search opens at (and re-proves) the
/// hot boundary instead of bisecting from scratch; results are identical
/// to cold sweeps (see `sweep::sweep_seeded`).
pub fn profile_dimm(backend: &mut dyn ProfilingBackend, dimm: &Dimm)
                    -> Result<DimmProfile> {
    Ok(profile_dimm_seeded(backend, dimm, None)?.0)
}

/// [`profile_dimm`] with cache-aware warm seeding: the 85degC sweeps can
/// open at another module's 85degC frontiers (the fleet engine passes the
/// nearest cached archetype's), and this module's own 85degC frontiers are
/// returned alongside the profile so a cache can keep them as seed
/// material. Cross-silicon seeding is sound for the same reason the
/// region profiler's spatial-neighbor seeding is: `sweep_seeded` re-proves
/// every seeded boundary, so a seed only changes the search cost — a seed
/// from similar silicon converges in a couple of probe waves, a bad one
/// degrades to the cold bisection — never the result.
pub fn profile_dimm_seeded(backend: &mut dyn ProfilingBackend, dimm: &Dimm,
                           seed: Option<(&SweepResult, &SweepResult)>)
                           -> Result<(DimmProfile, SweepResult, SweepResult)> {
    let refresh85 = profile_refresh(backend, &dimm.arrays, 85.0)?;
    let tref_r = refresh85.safe_read_ms();
    let tref_w = refresh85.safe_write_ms();

    let a = &dimm.arrays;
    let read85 = sweep_seeded(backend, a, TestKind::Read, 85.0, tref_r,
                              seed.map(|s| s.0))?;
    let write85 = sweep_seeded(backend, a, TestKind::Write, 85.0, tref_w,
                               seed.map(|s| s.1))?;
    let read55 =
        sweep_seeded(backend, a, TestKind::Read, 55.0, tref_r, Some(&read85))?;
    let write55 = sweep_seeded(backend, a, TestKind::Write, 55.0, tref_w,
                               Some(&write85))?;

    let at = |temp: f64, read: &SweepResult, write: &SweepResult|
     -> Result<TimingProfile> {
        let best = |s: &SweepResult, what: &str| {
            s.best.clone().ok_or_else(|| anyhow::anyhow!(
                "dimm {} infeasible {what} sweep at {temp}C", dimm.id))
        };
        Ok(TimingProfile {
            temp_c: temp,
            tref_read_ms: tref_r,
            tref_write_ms: tref_w,
            read: best(read, "read")?,
            write: best(write, "write")?,
        })
    };

    let profile = DimmProfile {
        id: dimm.id,
        vendor: dimm.vendor.clone(),
        refresh85: refresh85.clone(),
        at85: at(85.0, &read85, &write85)?,
        at55: at(55.0, &read55, &write55)?,
    };
    Ok((profile, read85, write85))
}

/// Timing characterization of one (bank, row-region) cell sub-population
/// at both profiled temperatures. Refresh intervals are module-level
/// (refresh hardware is per-rank, not per-region).
#[derive(Debug, Clone, PartialEq)]
pub struct RegionProfile {
    pub bank: usize,
    pub region: usize,
    pub at85: TimingProfile,
    pub at55: TimingProfile,
}

/// A module profile extended with per-(bank, row-region) timing bins —
/// the registry format-v2 payload and the input to
/// `aldram::RegionTable::try_from_region_profile`.
#[derive(Debug, Clone, PartialEq)]
pub struct RegionDimmProfile {
    pub base: DimmProfile,
    pub regions_per_bank: usize,
    /// Bank-major: `regions[bank * regions_per_bank + region]`.
    pub regions: Vec<RegionProfile>,
}

/// Profile one DIMM at region granularity: the module battery first
/// (refresh sweep + module timing sweeps, identical to `profile_dimm`),
/// then a timing sweep per (bank, row-region) over that region's cells
/// (`CellArrays::region_view`), at the module's safe refresh intervals.
///
/// Cost control: each region's 85degC sweep is warm-started from its
/// *spatial neighbor* — the previous region of the same bank, or for a
/// bank's first region, region 0 of the previous bank. The spatial
/// variation map is smooth (per-bank offset + monotone row gradient), so
/// neighbors land at most a grid step apart and each seeded sweep
/// converges in a couple of probe waves instead of a full bisection;
/// seeding never changes results (`sweep::sweep_seeded` re-proves seeds).
/// The 55degC sweeps warm-start from the region's own 85degC frontier,
/// as in the module path.
pub fn profile_dimm_regions(backend: &mut dyn ProfilingBackend, dimm: &Dimm,
                            regions_per_bank: usize)
                            -> Result<RegionDimmProfile> {
    anyhow::ensure!(regions_per_bank >= 1, "need at least one region");
    anyhow::ensure!(regions_per_bank <= dimm.arrays.cells,
                    "{regions_per_bank} regions over {} sampled cells",
                    dimm.arrays.cells);
    let base = profile_dimm(backend, dimm)?;
    let tref_r = base.at85.tref_read_ms;
    let tref_w = base.at85.tref_write_ms;

    let banks = dimm.arrays.banks;
    let mut regions = Vec::with_capacity(banks * regions_per_bank);
    // Seeds for the next region-0 sweep (previous bank's region 0) and
    // for the next in-bank sweep (previous region of this bank).
    let mut bank0_seed: Option<(SweepResult, SweepResult)> = None;
    for b in 0..banks {
        let mut prev: Option<(SweepResult, SweepResult)> = None;
        for r in 0..regions_per_bank {
            let view = dimm.arrays.region_view(b, r, regions_per_bank);
            let seed = if r > 0 { prev.as_ref() } else { bank0_seed.as_ref() };
            let read85 = sweep_seeded(backend, &view, TestKind::Read, 85.0,
                                      tref_r, seed.map(|s| &s.0))?;
            let write85 = sweep_seeded(backend, &view, TestKind::Write, 85.0,
                                       tref_w, seed.map(|s| &s.1))?;
            let read55 = sweep_seeded(backend, &view, TestKind::Read, 55.0,
                                      tref_r, Some(&read85))?;
            let write55 = sweep_seeded(backend, &view, TestKind::Write, 55.0,
                                       tref_w, Some(&write85))?;
            let at = |temp: f64, read: &SweepResult, write: &SweepResult|
             -> Result<TimingProfile> {
                let best = |s: &SweepResult, what: &str| {
                    s.best.clone().ok_or_else(|| anyhow::anyhow!(
                        "dimm {} bank {b} region {r} infeasible {what} \
                         sweep at {temp}C", dimm.id))
                };
                Ok(TimingProfile {
                    temp_c: temp,
                    tref_read_ms: tref_r,
                    tref_write_ms: tref_w,
                    read: best(read, "read")?,
                    write: best(write, "write")?,
                })
            };
            regions.push(RegionProfile {
                bank: b,
                region: r,
                at85: at(85.0, &read85, &write85)?,
                at55: at(55.0, &read55, &write55)?,
            });
            if r == 0 {
                bank0_seed = Some((read85.clone(), write85.clone()));
            }
            prev = Some((read85, write85));
        }
    }
    Ok(RegionDimmProfile { base, regions_per_bank, regions })
}

/// Population-level summary (the numbers quoted in §5.2 / Fig 3c-d).
#[derive(Debug, Clone)]
pub struct PopulationSummary {
    pub n_dimms: usize,
    /// Average fractional reduction of the read/write latency sums.
    pub read_reduction_85: f64,
    pub read_reduction_55: f64,
    pub write_reduction_85: f64,
    pub write_reduction_55: f64,
    /// Average per-parameter reductions [tRCD, tRAS, tWR, tRP].
    pub param_reduction_85: [f64; 4],
    pub param_reduction_55: [f64; 4],
    /// Most conservative (min across DIMMs) per-parameter reductions at
    /// 55degC — the operating point the paper's real-system evaluation
    /// uses ("minimum values ... that do not introduce errors for any
    /// module").
    pub min_param_reduction_55: [f64; 4],
}

pub fn summarize(profiles: &[DimmProfile]) -> PopulationSummary {
    assert!(!profiles.is_empty());
    let col =
        |f: &dyn Fn(&DimmProfile) -> f64| -> Vec<f64> {
            profiles.iter().map(f).collect()
        };
    let avg4 = |f: &dyn Fn(&DimmProfile) -> [f64; 4]| -> [f64; 4] {
        let mut acc = [0.0; 4];
        for p in profiles {
            let v = f(p);
            for i in 0..4 {
                acc[i] += v[i];
            }
        }
        acc.map(|x| x / profiles.len() as f64)
    };
    let min4 = |f: &dyn Fn(&DimmProfile) -> [f64; 4]| -> [f64; 4] {
        let mut acc = [f64::MAX; 4];
        for p in profiles {
            let v = f(p);
            for i in 0..4 {
                acc[i] = acc[i].min(v[i]);
            }
        }
        acc
    };
    PopulationSummary {
        n_dimms: profiles.len(),
        read_reduction_85: util::mean(&col(&|p| p.at85.read.reduction)),
        read_reduction_55: util::mean(&col(&|p| p.at55.read.reduction)),
        write_reduction_85: util::mean(&col(&|p| p.at85.write.reduction)),
        write_reduction_55: util::mean(&col(&|p| p.at55.write.reduction)),
        param_reduction_85: avg4(&|p| p.at85.param_reductions()),
        param_reduction_55: avg4(&|p| p.at55.param_reductions()),
        min_param_reduction_55: min4(&|p| p.at55.param_reductions()),
    }
}

/// Verify an operational timing set against a DIMM: zero errors for both
/// chains, each at its own (safe) refresh interval — the final check
/// AL-DRAM performs before installing a table entry. (Operationally the
/// system refreshes at the 64 ms standard; profiling at the safe interval
/// is the extra guardband of §5.1.)
pub fn verify_timings(backend: &mut dyn ProfilingBackend, dimm: &Dimm,
                      t: &TimingParams, temp_c: f64, tref_read_ms: f64,
                      tref_write_ms: f64) -> Result<bool> {
    let combo = |tref: f64| Combo {
        trcd: t.trcd_ns as f32,
        tras: t.tras_ns as f32,
        twr: t.twr_ns as f32,
        trp: t.trp_ns as f32,
        tref_ms: tref as f32,
        temp_c: temp_c as f32,
    };
    let combos = [combo(tref_read_ms), combo(tref_write_ms)];
    let out = backend.profile(&dimm.arrays, &combos)?;
    Ok(out.read_errors(0) == 0.0 && out.write_errors(1) == 0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params;
    use crate::population::generate_dimm;
    use crate::runtime::NativeBackend;

    #[test]
    fn profile_dimm_end_to_end() {
        let d = generate_dimm(3, 64, params());
        let mut b = NativeBackend::new();
        let p = profile_dimm(&mut b, &d).unwrap();
        // 55C must allow at least as much reduction as 85C.
        assert!(p.at55.read.reduction >= p.at85.read.reduction - 1e-9);
        assert!(p.at55.write.reduction >= p.at85.write.reduction - 1e-9);
        // Combined set must verify clean at both temps.
        for tp in [&p.at85, &p.at55] {
            let ok = verify_timings(&mut b, &d, &tp.combined(), tp.temp_c,
                                    tp.tref_read_ms, tp.tref_write_ms)
                .unwrap();
            assert!(ok, "combined timings fail verification at {}", tp.temp_c);
        }
    }

    #[test]
    fn combined_takes_conservative_trcd_trp() {
        let d = generate_dimm(10, 64, params());
        let mut b = NativeBackend::new();
        let p = profile_dimm(&mut b, &d).unwrap();
        let c = p.at55.combined();
        assert!(c.trcd_ns >= p.at55.read.trcd_ns.min(p.at55.write.trcd_ns));
        assert!(c.trcd_ns >= p.at55.read.trcd_ns.max(p.at55.write.trcd_ns) - 1e-9);
        assert!(c.trp_ns >= p.at55.read.trp_ns.max(p.at55.write.trp_ns) - 1e-9);
    }

    #[test]
    fn summary_averages() {
        let mut b = NativeBackend::new();
        let profiles: Vec<DimmProfile> = (0..3)
            .map(|id| {
                let d = generate_dimm(id, 64, params());
                profile_dimm(&mut b, &d).unwrap()
            })
            .collect();
        let s = summarize(&profiles);
        assert_eq!(s.n_dimms, 3);
        assert!(s.read_reduction_55 >= s.read_reduction_85 - 1e-9);
        for i in 0..4 {
            assert!(s.min_param_reduction_55[i]
                    <= s.param_reduction_55[i] + 1e-9);
        }
    }
}
